//! Calibration: profile the performance model and fit the Balancer's
//! linear predictors — the same procedure the paper runs on real GPUs.
//!
//! The paper's Balancer never sees ground-truth execution times; it uses
//! coefficients from linear regression on *profiled* data (Eq. 2 achieves
//! R²=0.993 / MAPE 7.4% for prefill on A30; Eq. 3 achieves R²=0.990 /
//! MAPE 0.8% for chunked iterations on A100 — Fig. 3).  We reproduce the
//! pipeline: sample iteration times from [`PerfModel`] with multiplicative
//! measurement noise, then OLS-fit the paper's functional forms.  The
//! `fig3_linear_fit` bench prints the resulting fit table.

use crate::simgpu::perfmodel::{IterationShape, PerfModel, PrefillSeg};
use crate::util::rng::Rng;
use crate::util::stats::{ols, Fit};

/// Eq. 2 coefficients: `T_prefill(L) = k_p · L + b_p`.
#[derive(Clone, Copy, Debug)]
pub struct PrefillCoeffs {
    pub k_p: f64,
    pub b_p: f64,
    pub r2: f64,
    pub mape: f64,
}

impl PrefillCoeffs {
    pub fn predict(&self, len: usize) -> f64 {
        self.k_p * len as f64 + self.b_p
    }
}

/// Eq. 3 coefficients:
/// `t_chunked = k_ctxp · L(R^P2) + k_ctxd · Σ L(R^D) + b_c`.
#[derive(Clone, Copy, Debug)]
pub struct ChunkedCoeffs {
    pub k_ctxp: f64,
    pub k_ctxd: f64,
    pub b_c: f64,
    pub r2: f64,
    pub mape: f64,
}

impl ChunkedCoeffs {
    pub fn predict(&self, prefill_ctx: f64, decode_ctx_sum: f64) -> f64 {
        self.k_ctxp * prefill_ctx + self.k_ctxd * decode_ctx_sum + self.b_c
    }
}

/// One profiled chunked-iteration sample (the dots in Fig. 3).
#[derive(Clone, Copy, Debug)]
pub struct ChunkedSample {
    pub prefill_ctx: f64,
    pub decode_ctx_sum: f64,
    pub time_s: f64,
}

/// Profile whole-prompt prefill across a sweep of lengths, with
/// `noise` relative measurement error (e.g. 0.02 = ±2%).
pub fn profile_prefill(
    pm: &PerfModel,
    lengths: &[usize],
    noise: f64,
    rng: &mut Rng,
) -> Vec<(usize, f64)> {
    lengths
        .iter()
        .map(|&n| {
            let t = pm.prefill_time(n) * (1.0 + noise * rng.normal());
            (n, t.max(0.0))
        })
        .collect()
}

/// Fit Eq. 2 from profiled (length, time) samples.
pub fn fit_prefill(samples: &[(usize, f64)]) -> Option<PrefillCoeffs> {
    let rows: Vec<Vec<f64>> =
        samples.iter().map(|(n, _)| vec![*n as f64]).collect();
    let ys: Vec<f64> = samples.iter().map(|(_, t)| *t).collect();
    let fit = ols(&rows, &ys)?;
    Some(PrefillCoeffs {
        k_p: fit.beta[0],
        b_p: fit.beta[1],
        r2: fit.r2,
        mape: fit.mape,
    })
}

/// Profile chunked-prefill iterations over a (prefill-context ×
/// decode-context) grid at a fixed token budget, as in Fig. 3:
/// every iteration batches `chunk` prefill tokens with `n_decode`
/// decode requests of average context `decode_ctx_sum / n_decode`.
pub fn profile_chunked(
    pm: &PerfModel,
    chunk: usize,
    prefill_ctxs: &[usize],
    decode_ctx_sums: &[usize],
    n_decode: usize,
    noise: f64,
    rng: &mut Rng,
) -> Vec<ChunkedSample> {
    let mut out = Vec::with_capacity(prefill_ctxs.len() * decode_ctx_sums.len());
    for &pc in prefill_ctxs {
        for &dc in decode_ctx_sums {
            let shape = IterationShape {
                prefill: vec![PrefillSeg { q_tokens: chunk, ctx_end: pc }],
                n_decode,
                decode_ctx_sum: dc,
            };
            let t = pm.iteration_time(&shape) * (1.0 + noise * rng.normal());
            out.push(ChunkedSample {
                prefill_ctx: pc as f64,
                decode_ctx_sum: dc as f64,
                time_s: t.max(0.0),
            });
        }
    }
    out
}

/// Fit Eq. 3 from profiled samples.
pub fn fit_chunked(samples: &[ChunkedSample]) -> Option<ChunkedCoeffs> {
    let rows: Vec<Vec<f64>> = samples
        .iter()
        .map(|s| vec![s.prefill_ctx, s.decode_ctx_sum])
        .collect();
    let ys: Vec<f64> = samples.iter().map(|s| s.time_s).collect();
    let fit: Fit = ols(&rows, &ys)?;
    Some(ChunkedCoeffs {
        k_ctxp: fit.beta[0],
        k_ctxd: fit.beta[1],
        b_c: fit.beta[2],
        r2: fit.r2,
        mape: fit.mape,
    })
}

/// Standard calibration sweep used by the Balancer and benches: profiles
/// both predictors for one (GPU pair, model) deployment.
pub fn calibrate(
    ppi_pm: &PerfModel,
    cpi_pm: &PerfModel,
    chunk: usize,
    noise: f64,
    seed: u64,
) -> (PrefillCoeffs, ChunkedCoeffs) {
    let mut rng = Rng::new(seed);
    let lengths: Vec<usize> = (1..=16).map(|i| i * 512).collect();
    let prefill = fit_prefill(&profile_prefill(ppi_pm, &lengths, noise, &mut rng))
        .expect("prefill fit");
    let prefill_ctxs: Vec<usize> = (1..=16).map(|i| i * 512).collect();
    let decode_ctx_sums: Vec<usize> = (0..=8).map(|i| i * 16_384).collect();
    let chunked = fit_chunked(&profile_chunked(
        cpi_pm,
        chunk,
        &prefill_ctxs,
        &decode_ctx_sums,
        48,
        noise,
        &mut rng,
    ))
    .expect("chunked fit");
    (prefill, chunked)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simgpu::model_desc::LLAMA3_8B;
    use crate::simgpu::spec::{A100, A30};

    #[test]
    fn prefill_fit_matches_paper_quality() {
        // Paper: R² = 0.993, MAPE 7.4% for LLaMA3-8B prefill on A30.
        let pm = PerfModel::new(A30, LLAMA3_8B);
        let mut rng = Rng::new(1);
        let lengths: Vec<usize> = (1..=16).map(|i| i * 512).collect();
        let samples = profile_prefill(&pm, &lengths, 0.05, &mut rng);
        let fit = fit_prefill(&samples).unwrap();
        assert!(fit.r2 > 0.97, "r2 {}", fit.r2);
        assert!(fit.mape < 0.10, "mape {}", fit.mape);
        assert!(fit.k_p > 0.0);
    }

    #[test]
    fn chunked_fit_matches_paper_quality() {
        // Paper (Fig. 3): R² = 0.990, MAPE 0.8% on A100.
        let pm = PerfModel::new(A100, LLAMA3_8B);
        let mut rng = Rng::new(2);
        let pcs: Vec<usize> = (1..=16).map(|i| i * 512).collect();
        let dcs: Vec<usize> = (0..=8).map(|i| i * 16_384).collect();
        // ±0.5% measurement noise (the paper's overall MAPE is 0.8%).
        let samples = profile_chunked(&pm, 512, &pcs, &dcs, 48, 0.005, &mut rng);
        let fit = fit_chunked(&samples).unwrap();
        assert!(fit.r2 > 0.985, "r2 {}", fit.r2);
        assert!(fit.mape < 0.01, "mape {}", fit.mape);
        assert!(fit.k_ctxp > 0.0 && fit.k_ctxd > 0.0 && fit.b_c > 0.0);
    }

    #[test]
    fn noiseless_fit_is_exact() {
        let pm = PerfModel::new(A100, LLAMA3_8B);
        let mut rng = Rng::new(3);
        let pcs: Vec<usize> = (1..=8).map(|i| i * 512).collect();
        let dcs: Vec<usize> = (0..=4).map(|i| i * 8192).collect();
        let samples = profile_chunked(&pm, 512, &pcs, &dcs, 32, 0.0, &mut rng);
        let fit = fit_chunked(&samples).unwrap();
        assert!(fit.r2 > 0.9999, "r2 {}", fit.r2);
        // Predictions must match the model to <1%.
        for s in &samples {
            let pred = fit.predict(s.prefill_ctx, s.decode_ctx_sum);
            assert!(((pred - s.time_s) / s.time_s).abs() < 0.01);
        }
    }

    #[test]
    fn predictor_coefficients_have_physical_meaning() {
        let pm = PerfModel::new(A100, LLAMA3_8B);
        let (_, chunked) =
            calibrate(&PerfModel::new(A30, LLAMA3_8B), &pm, 512, 0.0, 7);
        // k_ctxp: time per token of prefill context with a 512 chunk.
        let expected_kp = LLAMA3_8B.attn_flops(512.0, 1.0, 1.0) / A100.flops();
        assert!(
            ((chunked.k_ctxp - expected_kp) / expected_kp).abs() < 0.05,
            "k_ctxp {} vs {}",
            chunked.k_ctxp,
            expected_kp
        );
        // k_ctxd: time per decode-context token = KV bytes / bandwidth.
        let expected_kd = LLAMA3_8B.kv_bytes_per_token() as f64 / A100.bandwidth();
        assert!(
            ((chunked.k_ctxd - expected_kd) / expected_kd).abs() < 0.05,
            "k_ctxd {} vs {}",
            chunked.k_ctxd,
            expected_kd
        );
    }

    #[test]
    fn calibrate_is_deterministic() {
        let ppi = PerfModel::new(A30, LLAMA3_8B);
        let cpi = PerfModel::new(A100, LLAMA3_8B);
        let (p1, c1) = calibrate(&ppi, &cpi, 512, 0.02, 42);
        let (p2, c2) = calibrate(&ppi, &cpi, 512, 0.02, 42);
        assert_eq!(p1.k_p, p2.k_p);
        assert_eq!(c1.b_c, c2.b_c);
    }
}
