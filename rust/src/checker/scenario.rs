//! Scenario capsules: one TOML file that fully determines a run.
//!
//! A [`Scenario`] bundles everything that shapes a simulation — the
//! cluster topology and link fabric, the workload (open-loop trace,
//! explicit request list, or closed-loop sessions), the arrival
//! process, routing policy, SLO, autoscaling, fault plan, QoS classes,
//! and every seed — so a fuzz case, chaos case, or bench config becomes
//! a single portable artifact.  [`Scenario::to_toml`] /
//! [`Scenario::from_toml`] round-trip byte-for-byte (the `[topology]`
//! contract, extended to the whole run), and `cronus repro <case.toml>`
//! replays a capsule under the invariant oracle.
//!
//! [`InjectSpec`] is the corruption knob behind the harness's own
//! tests: it deterministically damages a finished run's event stream or
//! report *before* the oracle sees them, turning a healthy scenario
//! into a reproducible known-failing one — the seed material for shrink
//! smoke tests and CI.

use crate::config::toml::{self, TomlDoc, TomlValue};
use crate::config::topology::ClusterConfig;
use crate::cronus::router::RoutePolicy;
use crate::faults::FaultConfig;
use crate::metrics::Report;
use crate::qos::{ClassId, ClassRegistry};
use crate::simclock::SimTime;
use crate::simgpu::model_desc::LLAMA3_8B;
use crate::systems::cluster::ClusterSystem;
use crate::systems::{AutoscaleConfig, SystemEvent};
use crate::workload::arrival::{stamp, ArrivalProcess};
use crate::workload::azure::{generate, AzureTraceConfig};
use crate::workload::session::{generate_sessions, Session, SessionConfig};
use crate::workload::Request;

/// The workload half of a scenario.
#[derive(Clone, Debug)]
pub enum WorkloadSpec {
    /// `n_requests` Azure-shaped requests (seeded by `trace_seed`),
    /// stamped with `arrival` and replayed open-loop.
    OpenLoop {
        n_requests: usize,
        trace_seed: u64,
        arrival: ArrivalProcess,
    },
    /// A literal request list — what shrinking reduces an open-loop
    /// workload to, so a minimal capsule carries its exact requests.
    Explicit { requests: Vec<Request> },
    /// Closed-loop multi-turn sessions.
    Sessions { sessions: SessionConfig },
}

/// One fully-determined run: parse with [`Scenario::from_toml`], replay
/// with [`crate::checker::shrink::run_scenario`].
#[derive(Clone, Debug)]
pub struct Scenario {
    pub name: String,
    /// Reserved for run-level seeding; the workload and fault generators
    /// carry their own seeds so a capsule is self-contained.
    pub seed: u64,
    pub policy: RoutePolicy,
    pub slo_ttft_s: Option<f64>,
    pub cluster: ClusterConfig,
    pub workload: WorkloadSpec,
    pub autoscale: Option<AutoscaleConfig>,
    pub faults: Option<FaultConfig>,
    pub classes: Option<ClassRegistry>,
    /// Post-run corruption applied before the oracle (harness
    /// self-tests only).
    pub inject: Option<InjectSpec>,
}

impl Scenario {
    /// A minimal healthy scenario: one pair, a small all-at-once trace.
    pub fn minimal(name: &str) -> Scenario {
        Scenario {
            name: name.to_string(),
            seed: 42,
            policy: RoutePolicy::RoundRobin,
            slo_ttft_s: None,
            cluster: ClusterConfig::mixed(1, LLAMA3_8B),
            workload: WorkloadSpec::OpenLoop {
                n_requests: 16,
                trace_seed: 1,
                arrival: ArrivalProcess::AllAtOnce,
            },
            autoscale: None,
            faults: None,
            classes: None,
            inject: None,
        }
    }

    /// Whether the fault plan would actually inject outages (an empty
    /// `[faults]` section only tunes retry backoff — token accounting
    /// stays exact).
    pub fn faults_active(&self) -> bool {
        self.faults
            .as_ref()
            .map(|f| f.n_failures > 0 || !f.schedule.is_empty())
            .unwrap_or(false)
    }

    /// Whether any inter-pair link is configured (cluster-wide or
    /// per-pair override) — gates the oracle's migration laws.
    pub fn link_configured(&self) -> bool {
        self.cluster.link.is_some()
            || self.cluster.pairs.iter().any(|p| p.link.is_some())
    }

    pub fn is_closed_loop(&self) -> bool {
        matches!(self.workload, WorkloadSpec::Sessions { .. })
    }

    /// Materialize the open-loop request trace (class-stamped
    /// round-robin across the registry when QoS classes are attached).
    /// Errors for closed-loop scenarios — drive those through
    /// [`Scenario::sessions`].
    pub fn trace(&self) -> Result<Vec<Request>, String> {
        let mut trace = match &self.workload {
            WorkloadSpec::OpenLoop { n_requests, trace_seed, arrival } => {
                arrival.validate().map_err(|e| e.to_string())?;
                stamp(
                    &generate(*n_requests, &AzureTraceConfig::default(), *trace_seed),
                    *arrival,
                )
            }
            WorkloadSpec::Explicit { requests } => requests.clone(),
            WorkloadSpec::Sessions { .. } => {
                return Err("closed-loop scenario has no open-loop trace".into())
            }
        };
        if let Some(reg) = &self.classes {
            let n = reg.len();
            for (i, r) in trace.iter_mut().enumerate() {
                *r = r.with_class(ClassId((i % n) as u16));
            }
        }
        Ok(trace)
    }

    /// Materialize the session workload (`None` for open-loop
    /// scenarios).
    pub fn sessions(&self) -> Option<Vec<Session>> {
        match &self.workload {
            WorkloadSpec::Sessions { sessions } => Some(generate_sessions(sessions)),
            _ => None,
        }
    }

    /// Build the serving system this scenario describes.
    pub fn build_system(&self) -> Result<ClusterSystem, String> {
        let mut sys = ClusterSystem::new(self.cluster.clone(), self.policy)
            .with_slo_ttft(self.slo_ttft_s);
        if let Some(a) = &self.autoscale {
            sys = sys.with_autoscale(a.clone());
        }
        if let Some(f) = &self.faults {
            sys = sys.with_faults(f.build_plan(self.cluster.n_pairs())?, f.backoff());
        }
        if let Some(c) = &self.classes {
            sys = sys.with_classes(c.clone());
        }
        Ok(sys)
    }

    /// Structural validation beyond what parsing enforces.
    pub fn validate(&self) -> Result<(), String> {
        if self.cluster.n_pairs() == 0 {
            return Err("scenario needs at least one pair".into());
        }
        match &self.workload {
            WorkloadSpec::OpenLoop { arrival, .. } => {
                arrival.validate().map_err(|e| e.to_string())?;
            }
            WorkloadSpec::Explicit { requests } => {
                let mut ids: Vec<u64> = requests.iter().map(|r| r.id).collect();
                ids.sort_unstable();
                ids.dedup();
                if ids.len() != requests.len() {
                    return Err("explicit requests must have unique ids".into());
                }
                for r in requests {
                    if r.input_len == 0 || r.output_len == 0 {
                        return Err(format!(
                            "request {} needs input_len and output_len >= 1",
                            r.id
                        ));
                    }
                }
            }
            WorkloadSpec::Sessions { sessions } => {
                if sessions.n_sessions == 0 {
                    return Err("session workload needs n_sessions >= 1".into());
                }
                if sessions.min_turns == 0 || sessions.min_turns > sessions.max_turns {
                    return Err("session turns need 1 <= min_turns <= max_turns".into());
                }
            }
        }
        if let Some(f) = &self.faults {
            for e in &f.schedule {
                if e.pair >= self.cluster.n_pairs() {
                    return Err(format!(
                        "fault on pair {} but the scenario has {} pairs",
                        e.pair,
                        self.cluster.n_pairs()
                    ));
                }
            }
        }
        Ok(())
    }

    /// Serialize the capsule.  Canonical: every parsed key is emitted,
    /// so `emit(parse(emit(s))) == emit(s)` byte-for-byte.
    pub fn to_toml(&self) -> String {
        let mut sections: Vec<String> = Vec::new();
        let mut head = String::from("[scenario]\n");
        head.push_str(&format!("name = \"{}\"\n", self.name));
        head.push_str(&format!("seed = {}\n", self.seed));
        head.push_str(&format!("policy = \"{}\"\n", self.policy.name()));
        if let Some(s) = self.slo_ttft_s {
            head.push_str(&format!("slo_ttft_s = {s}\n"));
        }
        if let Some(inj) = self.inject {
            head.push_str(&format!("inject = \"{}\"\n", inj.name()));
        }
        sections.push(head);

        let mut work = String::from("[workload]\n");
        match &self.workload {
            WorkloadSpec::OpenLoop { n_requests, trace_seed, arrival } => {
                work.push_str("kind = \"open-loop\"\n");
                work.push_str(&format!("n_requests = {n_requests}\n"));
                work.push_str(&format!("trace_seed = {trace_seed}\n"));
                match *arrival {
                    ArrivalProcess::AllAtOnce => {
                        work.push_str("arrival = \"all-at-once\"\n");
                    }
                    ArrivalProcess::FixedInterval { interval_s } => {
                        work.push_str("arrival = \"fixed\"\n");
                        work.push_str(&format!("interval_s = {interval_s}\n"));
                    }
                    ArrivalProcess::Poisson { rate_rps, seed } => {
                        work.push_str("arrival = \"poisson\"\n");
                        work.push_str(&format!("rate_rps = {rate_rps}\n"));
                        work.push_str(&format!("arrival_seed = {seed}\n"));
                    }
                    ArrivalProcess::Diurnal { period_s, peak_rps, trough_rps, seed } => {
                        work.push_str("arrival = \"diurnal\"\n");
                        work.push_str(&format!("period_s = {period_s}\n"));
                        work.push_str(&format!("peak_rps = {peak_rps}\n"));
                        work.push_str(&format!("trough_rps = {trough_rps}\n"));
                        work.push_str(&format!("arrival_seed = {seed}\n"));
                    }
                    ArrivalProcess::Bursty { base_rps, burst_rps, burst_len_s, seed } => {
                        work.push_str("arrival = \"bursty\"\n");
                        work.push_str(&format!("base_rps = {base_rps}\n"));
                        work.push_str(&format!("burst_rps = {burst_rps}\n"));
                        work.push_str(&format!("burst_len_s = {burst_len_s}\n"));
                        work.push_str(&format!("arrival_seed = {seed}\n"));
                    }
                }
            }
            WorkloadSpec::Explicit { requests } => {
                work.push_str("kind = \"explicit\"\n");
                let specs: Vec<String> = requests
                    .iter()
                    .map(|r| format!("\"{}\"", request_spec(r)))
                    .collect();
                work.push_str(&format!("requests = [{}]\n", specs.join(", ")));
            }
            WorkloadSpec::Sessions { .. } => {
                work.push_str("kind = \"sessions\"\n");
            }
        }
        sections.push(work);

        if let WorkloadSpec::Sessions { sessions } = &self.workload {
            sections.push(sessions_to_toml(sessions));
        }

        sections.push(self.cluster.to_toml());
        if let Some(a) = &self.autoscale {
            sections.push(a.to_toml());
        }
        if let Some(f) = &self.faults {
            sections.push(f.to_toml());
        }
        if let Some(c) = &self.classes {
            let t = c.to_toml();
            if !t.is_empty() {
                sections.push(t);
            }
        }
        sections.join("\n")
    }

    /// Parse a capsule.  Optional sections absent from the file stay
    /// `None`; the result is [`validate`](Scenario::validate)d.
    pub fn from_toml(text: &str) -> Result<Scenario, String> {
        let doc = toml::parse(text).map_err(|e| e.to_string())?;
        let name = doc.get_str("scenario.name").unwrap_or("scenario").to_string();
        let seed = doc.get_i64("scenario.seed").unwrap_or(42) as u64;
        let policy_name = doc.get_str("scenario.policy").unwrap_or("round-robin");
        let policy = RoutePolicy::from_name(policy_name)
            .ok_or_else(|| format!("unknown routing policy '{policy_name}'"))?;
        let slo_ttft_s = match doc.get_f64("scenario.slo_ttft_s") {
            Some(s) if s.is_finite() && s > 0.0 => Some(s),
            Some(s) => return Err(format!("scenario.slo_ttft_s must be > 0, got {s}")),
            None => None,
        };
        let inject = match doc.get_str("scenario.inject") {
            Some(n) => Some(
                InjectSpec::from_name(n)
                    .ok_or_else(|| format!("unknown inject spec '{n}'"))?,
            ),
            None => None,
        };

        let workload = parse_workload(&doc, seed)?;

        let mut cluster = ClusterConfig::mixed(1, LLAMA3_8B);
        cluster.apply_toml(&doc)?;

        let autoscale = if doc.section_keys("autoscale.").is_empty() {
            None
        } else {
            let mut a = AutoscaleConfig::default();
            a.apply_toml(&doc);
            Some(a)
        };
        let faults = if doc.section_keys("faults.").is_empty() {
            None
        } else {
            let mut f = FaultConfig::default();
            f.apply_toml(&doc)?;
            Some(f)
        };
        let classes = if doc.section_keys("classes.").is_empty() {
            None
        } else {
            let mut c = ClassRegistry::new();
            c.apply_toml(&doc)?;
            Some(c)
        };

        let s = Scenario {
            name,
            seed,
            policy,
            slo_ttft_s,
            cluster,
            workload,
            autoscale,
            faults,
            classes,
            inject,
        };
        s.validate()?;
        Ok(s)
    }
}

/// Render one explicit request: `<id>@<arrival_ns>:<input>/<output>`.
fn request_spec(r: &Request) -> String {
    format!("{}@{}:{}/{}", r.id, r.arrival_ns, r.input_len, r.output_len)
}

/// Parse one explicit request spec (inverse of [`request_spec`]).
pub fn parse_request_spec(text: &str) -> Result<Request, String> {
    let bad = |what: &str| {
        format!(
            "request spec '{text}': {what} \
             (grammar: <id>@<arrival_ns>:<input>/<output>)"
        )
    };
    let (id_s, rest) = text.split_once('@').ok_or_else(|| bad("missing '@'"))?;
    let (arr_s, lens) = rest.split_once(':').ok_or_else(|| bad("missing ':'"))?;
    let (in_s, out_s) = lens.split_once('/').ok_or_else(|| bad("missing '/'"))?;
    let id: u64 = id_s.trim().parse().map_err(|_| bad("bad id"))?;
    let arrival_ns: u64 = arr_s.trim().parse().map_err(|_| bad("bad arrival"))?;
    let input_len: usize = in_s.trim().parse().map_err(|_| bad("bad input_len"))?;
    let output_len: usize = out_s.trim().parse().map_err(|_| bad("bad output_len"))?;
    if input_len == 0 || output_len == 0 {
        return Err(bad("input_len and output_len must be >= 1"));
    }
    Ok(Request::new(id, arrival_ns, input_len, output_len))
}

fn parse_workload(doc: &TomlDoc, default_seed: u64) -> Result<WorkloadSpec, String> {
    let kind = doc.get_str("workload.kind").unwrap_or("open-loop");
    match kind {
        "open-loop" => {
            let n_requests = doc.get_i64("workload.n_requests").unwrap_or(64).max(0) as usize;
            let trace_seed = doc.get_i64("workload.trace_seed").unwrap_or(1) as u64;
            let arrival = parse_arrival(doc, default_seed)?;
            Ok(WorkloadSpec::OpenLoop { n_requests, trace_seed, arrival })
        }
        "explicit" => {
            let items = match doc.get("workload.requests") {
                Some(TomlValue::Array(items)) => items,
                Some(_) => return Err("workload.requests must be an array".into()),
                None => return Err("explicit workload needs workload.requests".into()),
            };
            let mut requests = Vec::with_capacity(items.len());
            for item in items {
                let text = item
                    .as_str()
                    .ok_or("workload.requests entries must be strings")?;
                requests.push(parse_request_spec(text)?);
            }
            Ok(WorkloadSpec::Explicit { requests })
        }
        "sessions" => {
            let mut cfg = SessionConfig::default();
            apply_sessions_toml(&mut cfg, doc);
            Ok(WorkloadSpec::Sessions { sessions: cfg })
        }
        other => Err(format!(
            "unknown workload.kind '{other}' (open-loop | explicit | sessions)"
        )),
    }
}

fn parse_arrival(doc: &TomlDoc, default_seed: u64) -> Result<ArrivalProcess, String> {
    let need = |key: &str| {
        doc.get_f64(&format!("workload.{key}"))
            .ok_or_else(|| format!("arrival process needs workload.{key}"))
    };
    let seed = doc
        .get_i64("workload.arrival_seed")
        .map(|x| x as u64)
        .unwrap_or(default_seed);
    let name = doc.get_str("workload.arrival").unwrap_or("all-at-once");
    let p = match name {
        "all-at-once" => return Ok(ArrivalProcess::AllAtOnce),
        "fixed" => ArrivalProcess::fixed(need("interval_s")?),
        "poisson" => ArrivalProcess::poisson(need("rate_rps")?, seed),
        "diurnal" => ArrivalProcess::diurnal(
            need("period_s")?,
            need("peak_rps")?,
            need("trough_rps")?,
            seed,
        ),
        "bursty" => ArrivalProcess::bursty(
            need("base_rps")?,
            need("burst_rps")?,
            need("burst_len_s")?,
            seed,
        ),
        other => {
            return Err(format!(
                "unknown arrival process '{other}' \
                 (all-at-once | fixed | poisson | diurnal | bursty)"
            ))
        }
    };
    p.map_err(|e| e.to_string())
}

/// Emit a canonical `[sessions]` section (every [`SessionConfig`] key).
fn sessions_to_toml(cfg: &SessionConfig) -> String {
    format!(
        "[sessions]\n\
         n_sessions = {}\n\
         min_turns = {}\n\
         max_turns = {}\n\
         think_mean_s = {}\n\
         start_window_s = {}\n\
         mean_new_input = {}\n\
         sigma_new_input = {}\n\
         min_new_input = {}\n\
         max_new_input = {}\n\
         mean_output = {}\n\
         sigma_output = {}\n\
         min_output = {}\n\
         max_output = {}\n\
         seed = {}\n",
        cfg.n_sessions,
        cfg.min_turns,
        cfg.max_turns,
        cfg.think_mean_s,
        cfg.start_window_s,
        cfg.mean_new_input,
        cfg.sigma_new_input,
        cfg.min_new_input,
        cfg.max_new_input,
        cfg.mean_output,
        cfg.sigma_output,
        cfg.min_output,
        cfg.max_output,
        cfg.seed,
    )
}

fn apply_sessions_toml(cfg: &mut SessionConfig, doc: &TomlDoc) {
    if let Some(x) = doc.get_i64("sessions.n_sessions") {
        cfg.n_sessions = x.max(0) as usize;
    }
    if let Some(x) = doc.get_i64("sessions.min_turns") {
        cfg.min_turns = x.max(0) as usize;
    }
    if let Some(x) = doc.get_i64("sessions.max_turns") {
        cfg.max_turns = x.max(0) as usize;
    }
    if let Some(x) = doc.get_f64("sessions.think_mean_s") {
        cfg.think_mean_s = x;
    }
    if let Some(x) = doc.get_f64("sessions.start_window_s") {
        cfg.start_window_s = x;
    }
    if let Some(x) = doc.get_f64("sessions.mean_new_input") {
        cfg.mean_new_input = x;
    }
    if let Some(x) = doc.get_f64("sessions.sigma_new_input") {
        cfg.sigma_new_input = x;
    }
    if let Some(x) = doc.get_i64("sessions.min_new_input") {
        cfg.min_new_input = x.max(1) as usize;
    }
    if let Some(x) = doc.get_i64("sessions.max_new_input") {
        cfg.max_new_input = x.max(1) as usize;
    }
    if let Some(x) = doc.get_f64("sessions.mean_output") {
        cfg.mean_output = x;
    }
    if let Some(x) = doc.get_f64("sessions.sigma_output") {
        cfg.sigma_output = x;
    }
    if let Some(x) = doc.get_i64("sessions.min_output") {
        cfg.min_output = x.max(1) as usize;
    }
    if let Some(x) = doc.get_i64("sessions.max_output") {
        cfg.max_output = x.max(1) as usize;
    }
    if let Some(x) = doc.get_i64("sessions.seed") {
        cfg.seed = x as u64;
    }
}

/// Deterministic post-run corruption for harness self-tests: each
/// variant damages the `(events, report)` pair in a way that trips
/// exactly one oracle law, so a capsule with `inject` set is a
/// reproducible known-failing scenario (and stays failing under
/// shrinking, which re-applies the corruption every probe).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum InjectSpec {
    /// Duplicate the first `Finished` event → double terminal.
    DoubleFinish,
    /// Delete the first `Finished` event → lost request.
    LoseTerminal,
    /// Delete one token event → token undercount.
    UndercountTokens,
    /// Swap the timestamps of the first and last events → time warp.
    TimeWarp,
    /// Claim a migration the events can't justify.
    PhantomMigration,
}

impl InjectSpec {
    pub const ALL: [InjectSpec; 5] = [
        InjectSpec::DoubleFinish,
        InjectSpec::LoseTerminal,
        InjectSpec::UndercountTokens,
        InjectSpec::TimeWarp,
        InjectSpec::PhantomMigration,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            InjectSpec::DoubleFinish => "double-finish",
            InjectSpec::LoseTerminal => "lose-terminal",
            InjectSpec::UndercountTokens => "undercount-tokens",
            InjectSpec::TimeWarp => "time-warp",
            InjectSpec::PhantomMigration => "phantom-migration",
        }
    }

    pub fn from_name(name: &str) -> Option<InjectSpec> {
        InjectSpec::ALL.iter().copied().find(|i| i.name() == name)
    }

    /// The violation kind this corruption is designed to trip — the
    /// default shrink property for capsules with `inject` set.
    pub fn expected_kind(&self) -> crate::checker::oracle::ViolationKind {
        use crate::checker::oracle::ViolationKind as K;
        match self {
            InjectSpec::DoubleFinish => K::DoubleTerminal,
            InjectSpec::LoseTerminal => K::LostRequest,
            InjectSpec::UndercountTokens => K::TokenCountMismatch,
            InjectSpec::TimeWarp => K::TimeRegression,
            InjectSpec::PhantomMigration => K::PhantomMigration,
        }
    }

    /// Corrupt a finished run in place.  No-op when the stream lacks the
    /// event the variant targets (e.g. an empty run).
    pub fn apply(&self, events: &mut Vec<SystemEvent>, report: &mut Report) {
        match self {
            InjectSpec::DoubleFinish => {
                if let Some(i) = events
                    .iter()
                    .position(|e| matches!(e, SystemEvent::Finished { .. }))
                {
                    let dup = events[i].clone();
                    events.insert(i + 1, dup);
                }
            }
            InjectSpec::LoseTerminal => {
                if let Some(i) = events
                    .iter()
                    .position(|e| matches!(e, SystemEvent::Finished { .. }))
                {
                    events.remove(i);
                }
            }
            InjectSpec::UndercountTokens => {
                let i = events
                    .iter()
                    .position(|e| matches!(e, SystemEvent::Token { .. }))
                    .or_else(|| {
                        events
                            .iter()
                            .position(|e| matches!(e, SystemEvent::FirstToken { .. }))
                    });
                if let Some(i) = i {
                    events.remove(i);
                }
            }
            InjectSpec::TimeWarp => {
                if events.len() >= 2 {
                    let t_first = events.first().unwrap().time();
                    let t_last = events.last().unwrap().time();
                    if t_first != t_last {
                        set_event_time(events.first_mut().unwrap(), t_last);
                        set_event_time(events.last_mut().unwrap(), t_first);
                    }
                }
            }
            InjectSpec::PhantomMigration => {
                report.n_migrations += 1;
                report.migrated_tokens = 0;
            }
        }
    }
}

fn set_event_time(ev: &mut SystemEvent, t: SimTime) {
    match ev {
        SystemEvent::FirstToken { t: x, .. }
        | SystemEvent::Token { t: x, .. }
        | SystemEvent::Finished { t: x, .. }
        | SystemEvent::Shed { t: x, .. }
        | SystemEvent::ScaleUp { t: x, .. }
        | SystemEvent::ScaleDown { t: x, .. }
        | SystemEvent::PairFailed { t: x, .. }
        | SystemEvent::PairRecovered { t: x, .. } => *x = t,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::faults::parse_schedule_entry;
    use crate::qos::ServiceClass;
    use crate::simgpu::link::LinkSpec;

    fn kitchen_sink() -> Scenario {
        let mut s = Scenario::minimal("kitchen-sink");
        s.seed = 7;
        s.policy = RoutePolicy::SloAware;
        s.slo_ttft_s = Some(2.5);
        s.cluster = ClusterConfig::mixed(4, LLAMA3_8B);
        s.cluster.link = Some(LinkSpec::parse("100G@2us:0.9").unwrap());
        s.workload = WorkloadSpec::OpenLoop {
            n_requests: 200,
            trace_seed: 11,
            arrival: ArrivalProcess::diurnal(60.0, 24.0, 4.0, 5).unwrap(),
        };
        s.autoscale = Some(AutoscaleConfig { min_pairs: 2, ..Default::default() });
        s.faults = Some(FaultConfig {
            n_failures: 2,
            schedule: vec![parse_schedule_entry("1@2.5+3").unwrap()],
            ..FaultConfig::default()
        });
        let mut reg = ClassRegistry::new();
        reg.register(ServiceClass { tier: 1, weight: 2.0, ..ServiceClass::named("premium") });
        s.classes = Some(reg);
        s.inject = Some(InjectSpec::DoubleFinish);
        s
    }

    #[test]
    fn scenario_toml_round_trips_byte_for_byte() {
        for s in [
            Scenario::minimal("tiny"),
            kitchen_sink(),
            Scenario {
                workload: WorkloadSpec::Explicit {
                    requests: vec![
                        parse_request_spec("0@0:512/64").unwrap(),
                        parse_request_spec("1@500000:256/32").unwrap(),
                    ],
                },
                ..Scenario::minimal("explicit")
            },
            Scenario {
                workload: WorkloadSpec::Sessions {
                    sessions: SessionConfig { n_sessions: 3, ..Default::default() },
                },
                ..Scenario::minimal("sessions")
            },
            Scenario {
                workload: WorkloadSpec::OpenLoop {
                    n_requests: 50,
                    trace_seed: 3,
                    arrival: ArrivalProcess::bursty(1.0, 40.0, 0.5, 9).unwrap(),
                },
                ..Scenario::minimal("bursty")
            },
        ] {
            let text = s.to_toml();
            let back = Scenario::from_toml(&text)
                .unwrap_or_else(|e| panic!("'{}' failed to re-parse: {e}\n{text}", s.name));
            assert_eq!(back.to_toml(), text, "'{}' must round-trip", s.name);
        }
    }

    #[test]
    fn parsed_scenario_preserves_structure() {
        let s = kitchen_sink();
        let back = Scenario::from_toml(&s.to_toml()).unwrap();
        assert_eq!(back.name, "kitchen-sink");
        assert_eq!(back.policy, RoutePolicy::SloAware);
        assert_eq!(back.slo_ttft_s, Some(2.5));
        assert_eq!(back.cluster.n_pairs(), 4);
        assert!(back.link_configured());
        assert!(back.faults_active());
        assert_eq!(back.inject, Some(InjectSpec::DoubleFinish));
        assert_eq!(back.classes.as_ref().unwrap().len(), 2);
        match back.workload {
            WorkloadSpec::OpenLoop { n_requests, arrival, .. } => {
                assert_eq!(n_requests, 200);
                assert!(matches!(arrival, ArrivalProcess::Diurnal { .. }));
            }
            other => panic!("wrong workload {other:?}"),
        }
    }

    #[test]
    fn bad_capsules_are_rejected() {
        assert!(Scenario::from_toml("[scenario]\npolicy = \"nope\"\n").is_err());
        assert!(Scenario::from_toml("[scenario]\ninject = \"nope\"\n").is_err());
        assert!(
            Scenario::from_toml("[workload]\nkind = \"open-loop\"\narrival = \"poisson\"\n")
                .is_err(),
            "poisson without a rate must be rejected"
        );
        assert!(Scenario::from_toml(
            "[workload]\nkind = \"open-loop\"\narrival = \"poisson\"\nrate_rps = -1\n"
        )
        .is_err());
        assert!(Scenario::from_toml(
            "[workload]\nkind = \"explicit\"\nrequests = [\"0@0:10/5\", \"0@1:10/5\"]\n"
        )
        .is_err());
        assert!(Scenario::from_toml("[scenario]\nslo_ttft_s = -2\n").is_err());
        assert!(parse_request_spec("1@2:0/5").is_err());
        assert!(parse_request_spec("garbage").is_err());
    }

    #[test]
    fn trace_stamps_classes_round_robin() {
        let mut s = Scenario::minimal("classes");
        let mut reg = ClassRegistry::new();
        reg.register(ServiceClass::named("premium"));
        s.classes = Some(reg);
        let trace = s.trace().unwrap();
        assert!(trace.iter().enumerate().all(|(i, r)| r.class.0 as usize == i % 2));
    }

    #[test]
    fn inject_names_round_trip() {
        for i in InjectSpec::ALL {
            assert_eq!(InjectSpec::from_name(i.name()), Some(i));
        }
        assert_eq!(InjectSpec::from_name("nope"), None);
    }
}
