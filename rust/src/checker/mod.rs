//! # Robustness harness: capsules, oracle, shrinking
//!
//! Three pieces that together turn "a fuzz seed failed somewhere" into
//! "here is a three-request TOML file that still fails":
//!
//! * [`scenario`] — **scenario capsules**.  A [`Scenario`] fully
//!   determines a run (topology, link fabric, workload + arrival
//!   process, routing policy, SLO, autoscaling, fault plan, QoS
//!   classes, seeds) and round-trips byte-for-byte through a single
//!   TOML file, so any failure is a portable artifact: check it into
//!   `cases/`, attach it to a bug report, replay it with
//!   `cronus repro <case.toml>`.
//! * [`oracle`] — the **online invariant oracle**.  [`InvariantChecker`]
//!   consumes the [`SystemEvent`](crate::systems::SystemEvent) stream
//!   incrementally (O(1) per event) and checks the conservation laws
//!   the test suites used to each re-implement: every submitted request
//!   ends `Finished` xor `Shed` exactly once, token events match
//!   `output_len`, event times are monotone, per-class counts conserve,
//!   and the report's counters agree with the events.
//! * [`shrink`] — **minimal-counterexample reduction**.
//!   [`shrink`](shrink::shrink) delta-debugs a failing scenario (halve
//!   the workload, ddmin requests and fault events, collapse the fleet,
//!   drop optional subsystems) while re-verifying the property at every
//!   step; [`check_scenarios`] wraps the fuzz loop so a failing suite
//!   panics with the path to a shrunk `repro_*.toml` instead of a seed.

pub mod oracle;
pub mod scenario;
pub mod shrink;

pub use oracle::{CheckSummary, InvariantChecker, Violation, ViolationKind};
pub use scenario::{InjectSpec, Scenario, WorkloadSpec};
pub use shrink::{
    check_scenarios, repro_dir, run_scenario, shrink_to_file, ScenarioRun, ShrinkOutcome,
};
