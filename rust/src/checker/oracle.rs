//! The online invariant oracle: one incremental checker for the
//! conservation, monotonicity, and token-accounting laws the fuzz and
//! property suites used to each re-implement.
//!
//! [`InvariantChecker`] consumes the [`SystemEvent`] stream *as it is
//! produced* — O(1) work and O(#requests) state per event, no buffering
//! of the stream — so it rides along on production-scale runs
//! (`bench-cluster --check`, `replay_trace_observed`) as easily as on a
//! collected test vector.  Feed it expectations
//! ([`expect_trace`](InvariantChecker::expect_trace) /
//! [`expect_sessions`](InvariantChecker::expect_sessions)), stream
//! events through [`on_event`](InvariantChecker::on_event), optionally
//! cross-check the final [`Report`] with
//! [`check_report`](InvariantChecker::check_report), and call
//! [`finish`](InvariantChecker::finish) for the verdict.
//!
//! The invariants, and the suite that previously owned each (see
//! ARCHITECTURE.md §Robustness harness for the full table):
//!
//! * every expected request ends `Finished` xor `Shed` **exactly once**
//!   (`session_fuzz`, `faults_chaos`) — [`ViolationKind::DoubleTerminal`]
//!   / [`ViolationKind::LostRequest`];
//! * a finished request emits exactly `output_len` token events
//!   (`FirstToken` counts as the first token) in fault-free runs, and at
//!   least `output_len` when a fault plan may abort and re-serve partial
//!   decodes (`property_invariants`, `faults_chaos`) —
//!   [`ViolationKind::TokenCountMismatch`];
//! * the event stream is monotone in simulation time
//!   (`property_invariants`) — [`ViolationKind::TimeRegression`];
//! * report counters agree with the events that justify them
//!   (`faults_chaos`, `tests/autoscale.rs`) —
//!   [`ViolationKind::CounterMismatch`] /
//!   [`ViolationKind::PhantomMigration`];
//! * per-class breakdowns conserve requests (`qos` suites) —
//!   [`ViolationKind::ClassConservation`].
//!
//! Driver-synthetic sheds (reason prefixed
//! [`SYNTHETIC_SHED_PREFIX`] — turns dropped at the retry cap, which the
//! *system* never saw) are terminals for conservation but are exempt
//! from the monotonicity clock and from per-class sums, mirroring how
//! the drivers fold them into the report after `drain()`.

use std::fmt;

use crate::metrics::Report;
use crate::simclock::SimTime;
use crate::systems::SystemEvent;
use crate::util::fxhash::FxHashMap;
use crate::workload::session::{turn_request_id, Session};
use crate::workload::Request;

/// Reason prefix of the sheds the drivers synthesize for requests
/// dropped at the retry cap ("dropped by the replay driver…" /
/// "dropped by the closed-loop driver…").
pub const SYNTHETIC_SHED_PREFIX: &str = "dropped by the";

/// Violations recorded verbatim before the checker starts counting
/// instead of storing (a corrupt run can violate once per event).
const MAX_VIOLATIONS: usize = 64;

/// The invariant class a [`Violation`] belongs to.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ViolationKind {
    /// An event carried an earlier timestamp than its predecessor.
    TimeRegression,
    /// A request reached a second terminal (`Finished`/`Shed`) event.
    DoubleTerminal,
    /// An expected request produced tokens or was required, but never
    /// reached a terminal event.
    LostRequest,
    /// A finished request's token-event count disagrees with its
    /// `output_len` (or a shed request emitted tokens in a fault-free
    /// run).
    TokenCountMismatch,
    /// A request-bearing event for an id no expectation covers.
    PhantomEvent,
    /// Migration counters without a configured link / migrated tokens,
    /// or migrated tokens without a migration.
    PhantomMigration,
    /// A report counter disagrees with the events that justify it.
    CounterMismatch,
    /// A per-class breakdown fails conservation, or the class sums
    /// disagree with the cluster totals.
    ClassConservation,
}

impl ViolationKind {
    pub fn name(&self) -> &'static str {
        match self {
            ViolationKind::TimeRegression => "time-regression",
            ViolationKind::DoubleTerminal => "double-terminal",
            ViolationKind::LostRequest => "lost-request",
            ViolationKind::TokenCountMismatch => "token-count-mismatch",
            ViolationKind::PhantomEvent => "phantom-event",
            ViolationKind::PhantomMigration => "phantom-migration",
            ViolationKind::CounterMismatch => "counter-mismatch",
            ViolationKind::ClassConservation => "class-conservation",
        }
    }
}

/// One recorded invariant violation.
#[derive(Clone, Debug)]
pub struct Violation {
    pub kind: ViolationKind,
    pub detail: String,
}

impl fmt::Display for Violation {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "[{}] {}", self.kind.name(), self.detail)
    }
}

/// What the workload promises about one request id.
struct Expect {
    want_tokens: usize,
    /// `true` = the request is definitely submitted (open-loop trace);
    /// `false` = it may legitimately never appear (a closed-loop turn
    /// after an aborted session).
    required: bool,
}

/// What the event stream has shown about one request id.
#[derive(Default)]
struct Progress {
    tokens: usize,
    n_finished: u32,
    n_shed: u32,
}

/// The verdict of one checked run.
#[derive(Debug, Default)]
pub struct CheckSummary {
    pub violations: Vec<Violation>,
    /// Events consumed by the checker.
    pub n_events: u64,
    /// Violations beyond [the storage cap](`MAX_VIOLATIONS`), counted
    /// but not recorded.
    pub n_suppressed: usize,
}

impl CheckSummary {
    /// No violations at all.
    pub fn ok(&self) -> bool {
        self.violations.is_empty() && self.n_suppressed == 0
    }

    /// Whether any recorded violation is of `kind`.
    pub fn has(&self, kind: ViolationKind) -> bool {
        self.violations.iter().any(|v| v.kind == kind)
    }

    /// Human-readable multi-line rendering of the verdict.
    pub fn render(&self) -> String {
        if self.ok() {
            return format!("ok: {} events, no invariant violations", self.n_events);
        }
        let mut out = format!(
            "{} invariant violation(s) over {} events",
            self.violations.len() + self.n_suppressed,
            self.n_events
        );
        for v in &self.violations {
            out.push_str(&format!("\n  {v}"));
        }
        if self.n_suppressed > 0 {
            out.push_str(&format!("\n  … and {} more (suppressed)", self.n_suppressed));
        }
        out
    }
}

/// Incremental invariant checker over one run's event stream.  See the
/// module docs for the laws it enforces.
pub struct InvariantChecker {
    expected: FxHashMap<u64, Expect>,
    seen: FxHashMap<u64, Progress>,
    /// Fault-free runs owe *exact* token conservation; with an active
    /// fault plan an aborted decode is re-served from scratch, so a
    /// finished request may emit more than `output_len` tokens and a
    /// shed one may have partial output.
    exact_tokens: bool,
    faults_planned: bool,
    link_configured: bool,
    has_expectations: bool,
    last_t: Option<SimTime>,
    n_events: u64,
    n_finished_ev: usize,
    n_shed_ev: usize,
    n_synthetic_shed_ev: usize,
    n_scale_up_ev: usize,
    n_scale_down_ev: usize,
    n_pair_failed_ev: usize,
    n_pair_recovered_ev: usize,
    violations: Vec<Violation>,
    n_suppressed: usize,
}

impl Default for InvariantChecker {
    fn default() -> Self {
        InvariantChecker::new()
    }
}

impl InvariantChecker {
    pub fn new() -> InvariantChecker {
        InvariantChecker {
            expected: FxHashMap::default(),
            seen: FxHashMap::default(),
            exact_tokens: true,
            faults_planned: false,
            link_configured: false,
            has_expectations: false,
            last_t: None,
            n_events: 0,
            n_finished_ev: 0,
            n_shed_ev: 0,
            n_synthetic_shed_ev: 0,
            n_scale_up_ev: 0,
            n_scale_down_ev: 0,
            n_pair_failed_ev: 0,
            n_pair_recovered_ev: 0,
            violations: Vec::new(),
            n_suppressed: 0,
        }
    }

    /// Declare whether a fault plan is active: switches token accounting
    /// from exact to at-least and legalizes `PairFailed` / retry
    /// counters.
    pub fn with_faults(mut self, active: bool) -> InvariantChecker {
        self.faults_planned = active;
        if active {
            self.exact_tokens = false;
        }
        self
    }

    /// Declare whether an inter-pair link is configured (gates the
    /// migration-counter laws).
    pub fn with_link(mut self, configured: bool) -> InvariantChecker {
        self.link_configured = configured;
        self
    }

    /// Expect every request of an open-loop trace: each must terminate
    /// exactly once.
    pub fn expect_trace(&mut self, trace: &[Request]) {
        for r in trace {
            self.expected.insert(
                r.id,
                Expect { want_tokens: r.output_len, required: true },
            );
        }
        self.has_expectations = true;
    }

    /// Expect the potential turns of a closed-loop session workload.
    /// Turns are *optional* (an aborted session never submits its later
    /// turns), but any turn that does appear is held to the same
    /// terminal and token laws.
    pub fn expect_sessions(&mut self, sessions: &[Session]) {
        for s in sessions {
            for (k, turn) in s.turns.iter().enumerate() {
                self.expected.insert(
                    turn_request_id(s.id, k),
                    Expect { want_tokens: turn.output_len, required: false },
                );
            }
        }
        self.has_expectations = true;
    }

    fn push(&mut self, kind: ViolationKind, detail: String) {
        if self.violations.len() < MAX_VIOLATIONS {
            self.violations.push(Violation { kind, detail });
        } else {
            self.n_suppressed += 1;
        }
    }

    /// Known to neither the expectations nor any earlier event.
    fn flag_phantom(&mut self, id: u64, what: &str) {
        if self.has_expectations
            && !self.expected.contains_key(&id)
            && !self.seen.contains_key(&id)
        {
            self.push(
                ViolationKind::PhantomEvent,
                format!("{what} for unexpected request id {id}"),
            );
        }
    }

    /// Consume one event.  O(1): a hash-map update and a few counters.
    pub fn on_event(&mut self, ev: &SystemEvent) {
        self.n_events += 1;
        let synthetic = matches!(
            ev,
            SystemEvent::Shed { reason, .. } if reason.starts_with(SYNTHETIC_SHED_PREFIX)
        );
        // Monotone simulation time.  Synthetic driver sheds are recorded
        // at their drop instant and merged by a stable sort, so they sit
        // outside the system's clock — skip them entirely.
        if !synthetic {
            let t = ev.time();
            if let Some(last) = self.last_t {
                if t < last {
                    self.push(
                        ViolationKind::TimeRegression,
                        format!(
                            "event at {:.6}s after one at {:.6}s ({ev:?})",
                            t.as_secs_f64(),
                            last.as_secs_f64()
                        ),
                    );
                }
            }
            self.last_t = Some(self.last_t.map_or(t, |l| l.max(t)));
        }
        match ev {
            SystemEvent::FirstToken { id, .. } | SystemEvent::Token { id, .. } => {
                self.flag_phantom(*id, "token event");
                self.seen.entry(*id).or_default().tokens += 1;
            }
            SystemEvent::Finished { id, .. } => {
                self.flag_phantom(*id, "Finished");
                self.n_finished_ev += 1;
                let p = self.seen.entry(*id).or_default();
                p.n_finished += 1;
                let terminals = p.n_finished + p.n_shed;
                if terminals == 2 {
                    self.push(
                        ViolationKind::DoubleTerminal,
                        format!("request {id} reached a second terminal (Finished)"),
                    );
                }
            }
            SystemEvent::Shed { id, .. } => {
                self.flag_phantom(*id, "Shed");
                self.n_shed_ev += 1;
                if synthetic {
                    self.n_synthetic_shed_ev += 1;
                }
                let p = self.seen.entry(*id).or_default();
                p.n_shed += 1;
                let terminals = p.n_finished + p.n_shed;
                if terminals == 2 {
                    self.push(
                        ViolationKind::DoubleTerminal,
                        format!("request {id} reached a second terminal (Shed)"),
                    );
                }
            }
            SystemEvent::ScaleUp { .. } => self.n_scale_up_ev += 1,
            SystemEvent::ScaleDown { .. } => self.n_scale_down_ev += 1,
            SystemEvent::PairFailed { pair, .. } => {
                self.n_pair_failed_ev += 1;
                if !self.faults_planned {
                    self.push(
                        ViolationKind::PhantomEvent,
                        format!("PairFailed({pair}) without a fault plan"),
                    );
                }
            }
            SystemEvent::PairRecovered { .. } => self.n_pair_recovered_ev += 1,
        }
    }

    /// Cross-check the final [`Report`] against the events witnessed:
    /// every counter the report exposes must be justified by the stream.
    pub fn check_report(&mut self, report: &Report) {
        let pairs: [(&str, usize, usize); 6] = [
            ("n_finished", report.n_finished, self.n_finished_ev),
            ("n_rejected", report.n_rejected, self.n_shed_ev),
            ("n_scale_ups", report.n_scale_ups, self.n_scale_up_ev),
            ("n_scale_downs", report.n_scale_downs, self.n_scale_down_ev),
            ("n_pair_failures", report.n_pair_failures, self.n_pair_failed_ev),
            ("n_recovered", report.n_recovered, self.n_pair_recovered_ev),
        ];
        for (name, reported, witnessed) in pairs {
            if reported != witnessed {
                self.push(
                    ViolationKind::CounterMismatch,
                    format!(
                        "report.{name} = {reported} but the stream shows {witnessed}"
                    ),
                );
            }
        }
        if report.n_requests != report.n_finished + report.n_rejected {
            self.push(
                ViolationKind::CounterMismatch,
                format!(
                    "n_requests {} != n_finished {} + n_rejected {}",
                    report.n_requests, report.n_finished, report.n_rejected
                ),
            );
        }
        if !self.faults_planned && report.n_retries > 0 {
            self.push(
                ViolationKind::CounterMismatch,
                format!("{} failure retries without a fault plan", report.n_retries),
            );
        }
        if report.n_retries > 0 && self.n_pair_failed_ev == 0 {
            self.push(
                ViolationKind::CounterMismatch,
                format!(
                    "{} failure retries but no PairFailed event",
                    report.n_retries
                ),
            );
        }
        let phantom_migration = (report.n_migrations > 0
            && (report.migrated_tokens == 0 || !self.link_configured))
            || (report.n_migrations == 0 && report.migrated_tokens > 0);
        if phantom_migration {
            self.push(
                ViolationKind::PhantomMigration,
                format!(
                    "n_migrations = {} / migrated_tokens = {} with link_configured = {}",
                    report.n_migrations, report.migrated_tokens, self.link_configured
                ),
            );
        }
        if !report.classes.is_empty() {
            let (mut sr, mut sf, mut ss) = (0usize, 0usize, 0usize);
            for c in &report.classes {
                if c.n_requests != c.n_finished + c.n_shed {
                    self.push(
                        ViolationKind::ClassConservation,
                        format!(
                            "class '{}': n_requests {} != n_finished {} + n_shed {}",
                            c.name, c.n_requests, c.n_finished, c.n_shed
                        ),
                    );
                }
                sr += c.n_requests;
                sf += c.n_finished;
                ss += c.n_shed;
            }
            // Driver-synthetic drops are folded into the cluster totals
            // after drain(), so the class sums trail them by exactly the
            // synthetic shed count.
            let syn = self.n_synthetic_shed_ev;
            if sr + syn != report.n_requests
                || sf != report.n_finished
                || ss + syn != report.n_rejected
            {
                self.push(
                    ViolationKind::ClassConservation,
                    format!(
                        "class sums (req {sr}, fin {sf}, shed {ss}) + {syn} synthetic \
                         != totals (req {}, fin {}, rej {})",
                        report.n_requests, report.n_finished, report.n_rejected
                    ),
                );
            }
        }
    }

    /// Close the stream: apply the end-of-run laws (lost requests, token
    /// conservation) and return the verdict.  Iterates expected ids in
    /// sorted order so the violation list is deterministic.
    pub fn finish(mut self) -> CheckSummary {
        let mut ids: Vec<u64> = self.expected.keys().copied().collect();
        ids.sort_unstable();
        let mut pending: Vec<Violation> = Vec::new();
        let mut suppressed = 0usize;
        let mut push = |kind: ViolationKind, detail: String| {
            if self.violations.len() + pending.len() < MAX_VIOLATIONS {
                pending.push(Violation { kind, detail });
            } else {
                suppressed += 1;
            }
        };
        for id in ids {
            let exp = &self.expected[&id];
            let (tokens, terminals, finished) = match self.seen.get(&id) {
                Some(p) => (p.tokens, p.n_finished + p.n_shed, p.n_finished > 0),
                None => (0, 0, false),
            };
            if terminals == 0 {
                if exp.required || tokens > 0 {
                    push(
                        ViolationKind::LostRequest,
                        format!(
                            "request {id} never reached a terminal event \
                             ({tokens} tokens seen)"
                        ),
                    );
                }
                continue;
            }
            if terminals > 1 {
                continue; // already flagged online
            }
            if finished {
                let bad = if self.exact_tokens {
                    tokens != exp.want_tokens
                } else {
                    tokens < exp.want_tokens
                };
                if bad {
                    push(
                        ViolationKind::TokenCountMismatch,
                        format!(
                            "request {id} finished with {tokens} token events, \
                             expected {}{}",
                            if self.exact_tokens { "" } else { ">= " },
                            exp.want_tokens
                        ),
                    );
                }
            } else if self.exact_tokens && tokens > 0 {
                push(
                    ViolationKind::TokenCountMismatch,
                    format!(
                        "request {id} was shed but emitted {tokens} token events \
                         in a fault-free run"
                    ),
                );
            }
        }
        self.violations.extend(pending);
        CheckSummary {
            violations: self.violations,
            n_events: self.n_events,
            n_suppressed: self.n_suppressed + suppressed,
        }
    }
}
