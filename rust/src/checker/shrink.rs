//! Replay a [`Scenario`] under the invariant oracle, and shrink failing
//! scenarios to minimal counterexamples.
//!
//! [`run_scenario`] is the harness's one entry point: build the system
//! the capsule describes, serve its workload with events collected,
//! apply the capsule's [`InjectSpec`] corruption (if any), and return
//! the oracle's verdict alongside the report and event stream.
//!
//! [`shrink`] is deterministic delta debugging over the scenario
//! structure.  Given a failing capsule and a property (a predicate on
//! [`ScenarioRun`] that holds exactly when the bug reproduces), it
//! alternates passes — halve the workload, ddmin the explicit request
//! list, collapse the fleet, freeze and ddmin the fault schedule, drop
//! optional subsystems, halve request lengths — until a fixpoint, and
//! every accepted step re-verifies the property, so the output is a
//! small capsule that *still fails the same way*.  [`shrink_to_file`]
//! writes it next to the run (`$CRONUS_REPRO_DIR` or the system temp
//! dir) as `repro_<label>.toml`, replayable with `cronus repro`.
//!
//! [`check_scenarios`] is the fuzz-loop harness the test suites use:
//! generate N seeded scenarios, replay each, and on the first property
//! failure shrink it and panic with the path to the minimal capsule —
//! a failing fuzz run hands you a file, not a seed to chase.

use std::path::PathBuf;

use crate::checker::oracle::{CheckSummary, InvariantChecker};
use crate::checker::scenario::{Scenario, WorkloadSpec};
use crate::metrics::Report;
use crate::systems::driver::{closed_loop_collect, replay_trace_collect};
use crate::systems::SystemEvent;
use crate::util::rng::Rng;
use crate::workload::arrival::{stamp, ArrivalProcess};
use crate::workload::azure::{generate, AzureTraceConfig};
use crate::workload::Request;

/// Everything one replay produced: the final report, the full event
/// stream (post-injection), the oracle's verdict, and the workload size
/// (requests submitted, or total session turns).
#[derive(Clone, Debug)]
pub struct ScenarioRun {
    pub report: Report,
    pub events: Vec<SystemEvent>,
    pub summary: CheckSummary,
    pub n_requests: usize,
}

/// Result of a shrink: the minimal scenario plus how much work it took.
#[derive(Clone, Debug)]
pub struct ShrinkOutcome {
    pub scenario: Scenario,
    /// Candidate replays executed (every accepted or rejected probe).
    pub probes: usize,
    /// Fixpoint iterations over the pass list.
    pub rounds: usize,
}

/// Hard caps so a pathological property cannot spin forever: the pass
/// loop stops after this many full rounds…
const MAX_ROUNDS: usize = 8;
/// …or this many candidate replays, whichever comes first.
const MAX_PROBES: usize = 4000;

/// Build, serve, corrupt (per `inject`), and judge one scenario.
pub fn run_scenario(s: &Scenario) -> Result<ScenarioRun, String> {
    s.validate()?;
    let mut sys = s.build_system()?;
    let mut checker = InvariantChecker::new()
        .with_faults(s.faults_active())
        .with_link(s.link_configured());
    let (outcome, mut events, n_requests) = if let Some(sessions) = s.sessions() {
        checker.expect_sessions(&sessions);
        let n: usize = sessions.iter().map(|x| x.turns.len()).sum();
        let (out, ev, _stats) = closed_loop_collect(&mut sys, &sessions);
        (out, ev, n)
    } else {
        let trace = s.trace()?;
        checker.expect_trace(&trace);
        let n = trace.len();
        let (out, ev, _stats) = replay_trace_collect(&mut sys, &trace);
        (out, ev, n)
    };
    let mut report = outcome.report;
    if let Some(inj) = s.inject {
        inj.apply(&mut events, &mut report);
    }
    for ev in &events {
        checker.on_event(ev);
    }
    checker.check_report(&report);
    Ok(ScenarioRun { report, events, summary: checker.finish(), n_requests })
}

/// Minimize `seed` while `fails` keeps returning `true`.  Errors if the
/// seed scenario does not fail the property in the first place (a
/// shrink of a healthy scenario would "converge" to noise).
pub fn shrink(
    seed: &Scenario,
    fails: &dyn Fn(&ScenarioRun) -> bool,
) -> Result<ShrinkOutcome, String> {
    let mut sh = Shrinker { fails, probes: 0 };
    let mut cur = seed.clone();
    if !sh.still_fails(&cur) {
        return Err(format!(
            "scenario '{}' does not fail the property; nothing to shrink",
            seed.name
        ));
    }
    let mut rounds = 0;
    loop {
        rounds += 1;
        let before = cur.to_toml();
        sh.pass_workload(&mut cur);
        sh.pass_ddmin_requests(&mut cur);
        sh.pass_fleet(&mut cur);
        sh.pass_faults(&mut cur);
        sh.pass_optionals(&mut cur);
        sh.pass_halve_fields(&mut cur);
        if cur.to_toml() == before || rounds >= MAX_ROUNDS || sh.probes >= MAX_PROBES {
            break;
        }
    }
    Ok(ShrinkOutcome { scenario: cur, probes: sh.probes, rounds })
}

/// Directory shrunk capsules are written to: `$CRONUS_REPRO_DIR` when
/// set (CI points it at an artifact dir), else the system temp dir.
pub fn repro_dir() -> PathBuf {
    match std::env::var_os("CRONUS_REPRO_DIR") {
        Some(d) if !d.is_empty() => PathBuf::from(d),
        _ => std::env::temp_dir(),
    }
}

/// [`shrink`], then write the minimal capsule to
/// `repro_dir()/repro_<label>.toml` and return its path.
pub fn shrink_to_file(
    seed: &Scenario,
    fails: &dyn Fn(&ScenarioRun) -> bool,
    label: &str,
) -> Result<(PathBuf, ShrinkOutcome), String> {
    let out = shrink(seed, fails)?;
    let dir = repro_dir();
    std::fs::create_dir_all(&dir)
        .map_err(|e| format!("creating {}: {e}", dir.display()))?;
    let safe: String = label
        .chars()
        .map(|c| if c.is_ascii_alphanumeric() || c == '-' || c == '_' { c } else { '_' })
        .collect();
    let path = dir.join(format!("repro_{safe}.toml"));
    std::fs::write(&path, out.scenario.to_toml())
        .map_err(|e| format!("writing {}: {e}", path.display()))?;
    Ok((path, out))
}

/// Fuzz-loop harness: replay `cases` seeded scenarios from `gen`; on
/// the first run where `fails` holds, shrink it and panic with the path
/// to the minimal `repro_*.toml` capsule.
///
/// Case seeds follow the repo's property-test convention: an FNV-1a
/// hash of `name` xor a per-case splitmix stride, so suites are stable
/// across runs and independent of each other.
pub fn check_scenarios(
    name: &str,
    cases: usize,
    gen: impl Fn(&mut Rng) -> Scenario,
    fails: impl Fn(&ScenarioRun) -> bool,
) {
    let base = fnv1a(name);
    for case in 0..cases {
        let mut rng = Rng::new(base ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15));
        let scenario = gen(&mut rng);
        let run = run_scenario(&scenario)
            .unwrap_or_else(|e| panic!("{name} case {case}: scenario failed to run: {e}"));
        if fails(&run) {
            let label = format!("{name}_case{case}");
            match shrink_to_file(&scenario, &fails, &label) {
                Ok((path, out)) => panic!(
                    "{name} case {case} violated the property.\n{}\n\
                     Minimal capsule ({} probes, {} rounds) written to {path_}\n\
                     Replay it with: cronus repro {path_}",
                    run.summary.render(),
                    out.probes,
                    out.rounds,
                    path_ = path.display(),
                ),
                Err(e) => panic!(
                    "{name} case {case} violated the property and shrinking errored ({e}).\n{}",
                    run.summary.render()
                ),
            }
        }
    }
}

fn fnv1a(s: &str) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

fn with_requests(cur: &Scenario, requests: Vec<Request>) -> Scenario {
    let mut cand = cur.clone();
    cand.workload = WorkloadSpec::Explicit { requests };
    cand
}

struct Shrinker<'a> {
    fails: &'a dyn Fn(&ScenarioRun) -> bool,
    probes: usize,
}

impl Shrinker<'_> {
    /// One probe: replay the candidate and test the property.  A
    /// candidate that errors (or blows the probe budget) counts as "no
    /// longer failing", so shrinking never accepts a broken scenario.
    fn still_fails(&mut self, cand: &Scenario) -> bool {
        if self.probes >= MAX_PROBES {
            return false;
        }
        self.probes += 1;
        match run_scenario(cand) {
            Ok(run) => (self.fails)(&run),
            Err(_) => false,
        }
    }

    fn try_accept(&mut self, cur: &mut Scenario, cand: Scenario) -> bool {
        if self.still_fails(&cand) {
            *cur = cand;
            true
        } else {
            false
        }
    }

    /// Shrink the workload generator itself: halve the request (or
    /// session) count, simplify the arrival process, and finally freeze
    /// an open-loop workload into an explicit request list so
    /// [`Shrinker::pass_ddmin_requests`] can bite.
    fn pass_workload(&mut self, cur: &mut Scenario) {
        match cur.workload.clone() {
            WorkloadSpec::OpenLoop { mut n_requests, trace_seed, arrival } => {
                while n_requests > 1 {
                    let half = n_requests / 2;
                    let mut cand = cur.clone();
                    cand.workload =
                        WorkloadSpec::OpenLoop { n_requests: half, trace_seed, arrival };
                    if !self.try_accept(cur, cand) {
                        break;
                    }
                    n_requests = half;
                }
                if !matches!(arrival, ArrivalProcess::AllAtOnce) {
                    let mut cand = cur.clone();
                    if let WorkloadSpec::OpenLoop { arrival: a, .. } = &mut cand.workload {
                        *a = ArrivalProcess::AllAtOnce;
                    }
                    self.try_accept(cur, cand);
                }
                if let WorkloadSpec::OpenLoop { n_requests, trace_seed, arrival } =
                    cur.workload
                {
                    let raw = stamp(
                        &generate(n_requests, &AzureTraceConfig::default(), trace_seed),
                        arrival,
                    );
                    // Keep only the four fields a capsule serializes, so
                    // the in-memory scenario matches its emitted TOML.
                    let requests: Vec<Request> = raw
                        .iter()
                        .map(|r| Request::new(r.id, r.arrival_ns, r.input_len, r.output_len))
                        .collect();
                    let cand = with_requests(cur, requests);
                    self.try_accept(cur, cand);
                }
            }
            WorkloadSpec::Sessions { sessions } => {
                let mut cfg = sessions;
                while cfg.n_sessions > 1 {
                    let mut next = cfg;
                    next.n_sessions /= 2;
                    let mut cand = cur.clone();
                    cand.workload = WorkloadSpec::Sessions { sessions: next };
                    if !self.try_accept(cur, cand) {
                        break;
                    }
                    cfg = next;
                }
                while cfg.max_turns > 1 {
                    let mut next = cfg;
                    next.min_turns = 1;
                    next.max_turns = (next.max_turns / 2).max(1);
                    let mut cand = cur.clone();
                    cand.workload = WorkloadSpec::Sessions { sessions: next };
                    if !self.try_accept(cur, cand) {
                        break;
                    }
                    cfg = next;
                }
            }
            WorkloadSpec::Explicit { .. } => {}
        }
    }

    /// Classic ddmin over the explicit request list.
    fn pass_ddmin_requests(&mut self, cur: &mut Scenario) {
        if let WorkloadSpec::Explicit { requests } = cur.workload.clone() {
            self.ddmin(cur, requests, false, &with_requests);
        }
    }

    /// Collapse the fleet: try one pair outright, then halve.  Fault
    /// schedule entries and autoscale bounds that name dropped pairs
    /// are clamped so every candidate is well-formed.
    fn pass_fleet(&mut self, cur: &mut Scenario) {
        loop {
            let n = cur.cluster.n_pairs();
            if n <= 1 {
                return;
            }
            if self.try_pairs(cur, 1) {
                continue;
            }
            if n / 2 >= 1 && n / 2 < n && self.try_pairs(cur, n / 2) {
                continue;
            }
            return;
        }
    }

    fn try_pairs(&mut self, cur: &mut Scenario, k: usize) -> bool {
        let mut cand = cur.clone();
        cand.cluster.pairs.truncate(k);
        if let Some(f) = &mut cand.faults {
            f.schedule.retain(|e| e.pair < k);
        }
        if let Some(a) = &mut cand.autoscale {
            a.min_pairs = a.min_pairs.min(k);
            a.initial_pairs = a.initial_pairs.min(k);
        }
        self.try_accept(cur, cand)
    }

    /// Simplify the fault plan: drop it entirely if the bug survives;
    /// otherwise freeze the seeded generator into an explicit schedule
    /// (behavior-identical, verified by the probe) and ddmin that.
    fn pass_faults(&mut self, cur: &mut Scenario) {
        if cur.faults.is_none() {
            return;
        }
        let mut cand = cur.clone();
        cand.faults = None;
        if self.try_accept(cur, cand) {
            return;
        }
        let f = cur.faults.clone().expect("checked above");
        if f.n_failures > 0 {
            if let Ok(plan) = f.build_plan(cur.cluster.n_pairs()) {
                let mut cand = cur.clone();
                if let Some(fc) = &mut cand.faults {
                    fc.schedule = plan.events().to_vec();
                    fc.n_failures = 0;
                }
                self.try_accept(cur, cand);
            }
        }
        if let Some(f) = cur.faults.clone() {
            if !f.schedule.is_empty() {
                self.ddmin(cur, f.schedule, true, &|s, items| {
                    let mut cand = s.clone();
                    if let Some(fc) = &mut cand.faults {
                        fc.schedule = items;
                    }
                    cand
                });
            }
        }
    }

    /// Drop optional subsystems one at a time: QoS classes, the SLO
    /// gate, autoscaling, and the inter-pair link fabric.
    fn pass_optionals(&mut self, cur: &mut Scenario) {
        if cur.classes.is_some() {
            let mut cand = cur.clone();
            cand.classes = None;
            self.try_accept(cur, cand);
        }
        if cur.slo_ttft_s.is_some() {
            let mut cand = cur.clone();
            cand.slo_ttft_s = None;
            self.try_accept(cur, cand);
        }
        if cur.autoscale.is_some() {
            let mut cand = cur.clone();
            cand.autoscale = None;
            self.try_accept(cur, cand);
        }
        if cur.link_configured() {
            let mut cand = cur.clone();
            cand.cluster.link = None;
            for p in &mut cand.cluster.pairs {
                p.link = None;
            }
            self.try_accept(cur, cand);
        }
    }

    /// Halve explicit requests' token lengths and zero their arrival
    /// offsets, to fixpoint.
    fn pass_halve_fields(&mut self, cur: &mut Scenario) {
        loop {
            let requests = match &cur.workload {
                WorkloadSpec::Explicit { requests } => requests.clone(),
                _ => return,
            };
            let mut progressed = false;
            let mutators: [fn(&mut Request); 3] = [
                |r| r.output_len = (r.output_len / 2).max(1),
                |r| r.input_len = (r.input_len / 2).max(1),
                |r| r.arrival_ns = 0,
            ];
            for mutate in mutators {
                let base = match &cur.workload {
                    WorkloadSpec::Explicit { requests } => requests.clone(),
                    _ => return,
                };
                let mut changed = false;
                let next: Vec<Request> = base
                    .iter()
                    .map(|r| {
                        let mut q = *r;
                        mutate(&mut q);
                        if q != *r {
                            changed = true;
                        }
                        q
                    })
                    .collect();
                if changed {
                    let cand = with_requests(cur, next);
                    if self.try_accept(cur, cand) {
                        progressed = true;
                    }
                }
            }
            let after = match &cur.workload {
                WorkloadSpec::Explicit { requests } => requests.clone(),
                _ => return,
            };
            if !progressed || after == requests || self.probes >= MAX_PROBES {
                return;
            }
        }
    }

    /// Delta debugging (Zeller's ddmin): remove complement chunks at
    /// increasing granularity until 1-minimal (or empty when
    /// `allow_empty`).  `build` turns a surviving item list into a
    /// candidate scenario.
    fn ddmin<T: Clone>(
        &mut self,
        cur: &mut Scenario,
        items: Vec<T>,
        allow_empty: bool,
        build: &dyn Fn(&Scenario, Vec<T>) -> Scenario,
    ) {
        let min_len = usize::from(!allow_empty);
        let mut items = items;
        if items.len() <= min_len {
            return;
        }
        let mut n = 2usize;
        loop {
            let chunk = items.len().div_ceil(n);
            let mut reduced = false;
            let mut start = 0;
            while start < items.len() {
                let end = (start + chunk).min(items.len());
                let mut rest: Vec<T> = Vec::with_capacity(items.len() - (end - start));
                rest.extend_from_slice(&items[..start]);
                rest.extend_from_slice(&items[end..]);
                if rest.len() < min_len {
                    start = end;
                    continue;
                }
                let cand = build(cur, rest.clone());
                if self.still_fails(&cand) {
                    *cur = cand;
                    items = rest;
                    n = n.saturating_sub(1).max(2);
                    reduced = true;
                    break;
                }
                start = end;
            }
            if !reduced {
                if n >= items.len() {
                    return;
                }
                n = (n * 2).min(items.len());
            }
            if items.len() <= min_len || self.probes >= MAX_PROBES {
                return;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::checker::oracle::ViolationKind;
    use crate::checker::scenario::InjectSpec;
    use crate::config::topology::ClusterConfig;
    use crate::faults::FaultConfig;
    use crate::simgpu::model_desc::LLAMA3_8B;
    use crate::workload::session::SessionConfig;

    #[test]
    fn healthy_scenario_passes_the_oracle() {
        let run = run_scenario(&Scenario::minimal("healthy")).unwrap();
        assert!(run.summary.ok(), "{}", run.summary.render());
        assert_eq!(run.n_requests, 16);
        assert!(run.report.n_finished > 0);
    }

    #[test]
    fn healthy_session_scenario_passes_the_oracle() {
        let mut s = Scenario::minimal("sessions");
        s.workload = WorkloadSpec::Sessions {
            sessions: SessionConfig { n_sessions: 4, ..Default::default() },
        };
        let run = run_scenario(&s).unwrap();
        assert!(run.summary.ok(), "{}", run.summary.render());
        assert!(run.n_requests >= 8, "4 sessions x >=2 turns");
    }

    #[test]
    fn every_injection_trips_its_target_invariant() {
        for inj in InjectSpec::ALL {
            let mut s = Scenario::minimal("inject");
            s.inject = Some(inj);
            let run = run_scenario(&s).unwrap();
            assert!(
                run.summary.has(inj.expected_kind()),
                "{} should trip {:?}, got: {}",
                inj.name(),
                inj.expected_kind(),
                run.summary.render()
            );
        }
    }

    #[test]
    fn shrink_refuses_a_healthy_seed() {
        let err = shrink(&Scenario::minimal("healthy"), &|run| !run.summary.ok());
        assert!(err.is_err());
    }

    fn failing_seed() -> Scenario {
        let mut s = Scenario::minimal("seeded-failure");
        s.cluster = ClusterConfig::mixed(2, LLAMA3_8B);
        s.workload = WorkloadSpec::OpenLoop {
            n_requests: 64,
            trace_seed: 3,
            arrival: ArrivalProcess::poisson(200.0, 7).unwrap(),
        };
        s.faults = Some(FaultConfig { n_failures: 1, ..FaultConfig::default() });
        s.inject = Some(InjectSpec::DoubleFinish);
        s
    }

    #[test]
    fn shrink_finds_a_tiny_double_finish_capsule() {
        let fails =
            |run: &ScenarioRun| run.summary.has(ViolationKind::DoubleTerminal);
        let out = shrink(&failing_seed(), &fails).unwrap();
        let s = &out.scenario;
        assert_eq!(s.cluster.n_pairs(), 1, "fleet should collapse to one pair");
        assert!(s.faults.is_none(), "fault plan is irrelevant to the bug");
        match &s.workload {
            WorkloadSpec::Explicit { requests } => {
                assert!(
                    requests.len() <= 3,
                    "expected <=3 requests, got {}",
                    requests.len()
                );
            }
            other => panic!("workload should be explicit, got {other:?}"),
        }
        // The minimal capsule must still fail the same way.
        let run = run_scenario(s).unwrap();
        assert!(fails(&run));
        // And shrinking is deterministic.
        let again = shrink(&failing_seed(), &fails).unwrap();
        assert_eq!(again.scenario.to_toml(), s.to_toml());
    }

    #[test]
    fn shrunk_capsule_round_trips_through_toml() {
        let fails =
            |run: &ScenarioRun| run.summary.has(ViolationKind::DoubleTerminal);
        let out = shrink(&failing_seed(), &fails).unwrap();
        let text = out.scenario.to_toml();
        let back = Scenario::from_toml(&text).unwrap();
        assert_eq!(back.to_toml(), text);
        let run = run_scenario(&back).unwrap();
        assert!(fails(&run), "reloaded capsule must still fail");
    }

    #[test]
    fn check_scenarios_accepts_healthy_generators() {
        check_scenarios(
            "shrink-smoke-healthy",
            3,
            |rng| {
                let mut s = Scenario::minimal("gen");
                s.workload = WorkloadSpec::OpenLoop {
                    n_requests: 4 + rng.range_usize(0, 8),
                    trace_seed: rng.range_usize(1, 100) as u64,
                    arrival: ArrivalProcess::AllAtOnce,
                };
                s
            },
            |run| !run.summary.ok(),
        );
    }
}
