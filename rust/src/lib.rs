//! # Cronus — partially disaggregated prefill for heterogeneous GPU clusters
//!
//! Production-quality reproduction of *“Cronus: Efficient LLM inference on
//! Heterogeneous GPU Clusters via Partially Disaggregated Prefill”*
//! (Liu, Xu & Hu, 2025) as a three-layer Rust + JAX + Pallas stack.
//!
//! * The **Rust coordinator** (this crate) implements the paper's
//!   contribution — the frontend Balancer, partial-prefill instance (PPI)
//!   and chunked-prefill instance (CPI) — plus every substrate it needs:
//!   a continuous-batching engine with chunked prefill, a paged KV-cache
//!   allocator, a heterogeneous-GPU performance model, a discrete-event
//!   simulator, workload generation, metrics, and all four baselines
//!   (DP+chunked, PP+chunked, disaggregated H→L and L→H).
//! * The **JAX model** and **Pallas kernels** (`python/compile/`) are
//!   AOT-lowered to HLO text once; [`runtime`] loads and executes them via
//!   the PJRT CPU client so the served tokens are real model output with
//!   Python never on the request path.
//!
//! Start with [`systems`] — the online `ServingSystem` trait
//! (`submit` / `advance` / `drain`) ties everything together, and
//! [`systems::driver::replay_trace`] replays recorded traces through it
//! for the batch experiments — or run `cargo run --example quickstart`.
//!
//! Beyond the paper's single pair, [`config::topology`] describes an
//! N-pair heterogeneous cluster, [`cronus::router`] routes requests
//! across the pairs (round-robin / least-outstanding-tokens / SLO-aware
//! / KV-affinity), and [`systems::cluster::ClusterSystem`] serves a
//! trace on the whole fleet — `cargo run --example cluster_scaleout`.
//! [`workload::session`] + [`systems::driver::closed_loop`] drive
//! multi-turn conversations closed-loop (think time between turns), the
//! regime where KV-affinity routing skips re-prefilling each turn's
//! replayed context — `cronus bench-cluster --closed-loop`.

#![allow(clippy::too_many_arguments, clippy::type_complexity)]

pub mod baselines;
pub mod benchkit;
pub mod checker;
pub mod config;
pub mod cronus;
pub mod engine;
pub mod faults;
pub mod kvcache;
pub mod launcher;
pub mod planner;
pub mod qos;
pub mod runtime;
pub mod server;
pub mod systems;
pub mod metrics;
pub mod simclock;
pub mod simgpu;
pub mod util;
pub mod workload;
