//! Workload substrate: request traces and arrival processes.
//!
//! The paper drives every experiment with 1000 conversation requests from
//! the Azure LLM inference trace 2023 (mean input 1014 tokens, mean
//! output 247), sent at fixed intervals (Fig. 4) or all at once
//! (Table 2's max-throughput measurement).  [`azure`] synthesizes traces
//! matching those statistics; [`arrival`] stamps arrival times.

pub mod arrival;
pub mod azure;

/// One inference request as the frontend sees it.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Request {
    pub id: u64,
    /// Arrival time at the frontend, nanoseconds since experiment start.
    pub arrival_ns: u64,
    /// Prompt length in tokens.
    pub input_len: usize,
    /// Response length in tokens (the trace records it; engines treat it
    /// as the step at which EOS is emitted).
    pub output_len: usize,
}

impl Request {
    pub fn total_context(&self) -> usize {
        self.input_len + self.output_len
    }
}

/// Summary statistics of a trace (used by tests and bench headers).
#[derive(Clone, Copy, Debug)]
pub struct TraceStats {
    pub n: usize,
    pub mean_input: f64,
    pub mean_output: f64,
    pub max_input: usize,
    pub max_output: usize,
}

pub fn stats(trace: &[Request]) -> TraceStats {
    let n = trace.len();
    let mean_input =
        trace.iter().map(|r| r.input_len as f64).sum::<f64>() / n.max(1) as f64;
    let mean_output =
        trace.iter().map(|r| r.output_len as f64).sum::<f64>() / n.max(1) as f64;
    TraceStats {
        n,
        mean_input,
        mean_output,
        max_input: trace.iter().map(|r| r.input_len).max().unwrap_or(0),
        max_output: trace.iter().map(|r| r.output_len).max().unwrap_or(0),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_of_fixed_trace() {
        let trace = vec![
            Request { id: 0, arrival_ns: 0, input_len: 100, output_len: 10 },
            Request { id: 1, arrival_ns: 0, input_len: 300, output_len: 30 },
        ];
        let s = stats(&trace);
        assert_eq!(s.n, 2);
        assert_eq!(s.mean_input, 200.0);
        assert_eq!(s.mean_output, 20.0);
        assert_eq!(s.max_input, 300);
    }

    #[test]
    fn total_context() {
        let r = Request { id: 0, arrival_ns: 0, input_len: 7, output_len: 3 };
        assert_eq!(r.total_context(), 10);
    }
}
