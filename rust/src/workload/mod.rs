//! Workload substrate: request traces, arrival processes, and multi-turn
//! conversation sessions.
//!
//! The paper drives every experiment with 1000 conversation requests from
//! the Azure LLM inference trace 2023 (mean input 1014 tokens, mean
//! output 247), sent at fixed intervals (Fig. 4) or all at once
//! (Table 2's max-throughput measurement).  [`azure`] synthesizes traces
//! matching those statistics; [`arrival`] stamps arrival times.
//! [`session`] generates *closed-loop* multi-turn conversations (each
//! turn's prompt replays the prior context, so follow-up turns can reuse
//! prefix KV resident on the pair that served the previous turn).

pub mod arrival;
pub mod azure;
pub mod session;

use crate::qos::ClassId;

/// [`Request::session_id`] value marking a standalone (sessionless)
/// single-shot request; real session ids start at 1.
pub const NO_SESSION: u64 = 0;

/// One inference request as the frontend sees it.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Request {
    pub id: u64,
    /// Arrival time at the frontend, nanoseconds since experiment start.
    pub arrival_ns: u64,
    /// Prompt length in tokens.
    pub input_len: usize,
    /// Response length in tokens (the trace records it; engines treat it
    /// as the step at which EOS is emitted).
    pub output_len: usize,
    /// Conversation this request belongs to ([`NO_SESSION`] for
    /// standalone requests).  Follow-up turns of the same session replay
    /// the prior context as a prompt prefix.
    pub session_id: u64,
    /// Leading `input_len` tokens that replay the session's prior context
    /// (previous turns' prompts + responses); 0 for first turns and
    /// standalone requests.  Always `< input_len` — every turn adds at
    /// least one fresh token.
    pub prefix_len: usize,
    /// Prefix tokens whose KV is *resident* on the system this request is
    /// dispatched to.  Granted by the cluster router when it routes a
    /// follow-up turn to the pair holding the session's KV; always
    /// `<= prefix_len`.  Workload generators leave it 0.
    pub kv_credit: usize,
    /// Last turn of its session: the router releases the session's KV
    /// residency once this request completes.
    pub final_turn: bool,
    /// Service class of the request's tenant (QoS: priority tier, fair
    /// share, per-class SLOs, model constraint — see [`crate::qos`]).
    /// Workload generators leave it at the built-in default class,
    /// which carries no contract and changes nothing.
    pub class: ClassId,
}

impl Request {
    /// A standalone (sessionless) request — the shape every pre-session
    /// workload generator produces.
    pub fn new(id: u64, arrival_ns: u64, input_len: usize, output_len: usize) -> Request {
        Request {
            id,
            arrival_ns,
            input_len,
            output_len,
            session_id: NO_SESSION,
            prefix_len: 0,
            kv_credit: 0,
            final_turn: false,
            class: ClassId::default(),
        }
    }

    /// The same request stamped into service class `class`.
    pub fn with_class(mut self, class: ClassId) -> Request {
        self.class = class;
        self
    }

    pub fn total_context(&self) -> usize {
        self.input_len + self.output_len
    }

    /// Prompt tokens that are genuinely new this turn (not a replay of
    /// the session's prior context).
    pub fn fresh_input(&self) -> usize {
        self.input_len - self.prefix_len
    }

    /// Clamp the router-granted resident-prefix credit to what a serving
    /// system can honour: never more than the declared session prefix,
    /// and never the whole prompt (at least one token is always
    /// computed — the engine asserts this).  Every credit-capable
    /// system calls this once at `submit` time.
    pub fn clamp_kv_credit(&mut self) {
        self.kv_credit = self
            .kv_credit
            .min(self.prefix_len)
            .min(self.input_len.saturating_sub(1));
    }

    /// Forget any session-prefix claim: the resident KV this request
    /// counted on died with its pair, so a fault-driven retry must
    /// re-prefill the whole prompt from scratch (and earn no warm-turn
    /// credit when re-routed).
    pub fn strip_kv_claim(&mut self) {
        self.prefix_len = 0;
        self.kv_credit = 0;
    }
}

/// Summary statistics of a trace (used by tests and bench headers).
#[derive(Clone, Copy, Debug)]
pub struct TraceStats {
    pub n: usize,
    pub mean_input: f64,
    pub mean_output: f64,
    pub max_input: usize,
    pub max_output: usize,
}

pub fn stats(trace: &[Request]) -> TraceStats {
    let n = trace.len();
    let mean_input =
        trace.iter().map(|r| r.input_len as f64).sum::<f64>() / n.max(1) as f64;
    let mean_output =
        trace.iter().map(|r| r.output_len as f64).sum::<f64>() / n.max(1) as f64;
    TraceStats {
        n,
        mean_input,
        mean_output,
        max_input: trace.iter().map(|r| r.input_len).max().unwrap_or(0),
        max_output: trace.iter().map(|r| r.output_len).max().unwrap_or(0),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_of_fixed_trace() {
        let trace = vec![Request::new(0, 0, 100, 10), Request::new(1, 0, 300, 30)];
        let s = stats(&trace);
        assert_eq!(s.n, 2);
        assert_eq!(s.mean_input, 200.0);
        assert_eq!(s.mean_output, 20.0);
        assert_eq!(s.max_input, 300);
    }

    #[test]
    fn total_context() {
        let r = Request::new(0, 0, 7, 3);
        assert_eq!(r.total_context(), 10);
        assert_eq!(r.session_id, NO_SESSION);
        assert_eq!(r.fresh_input(), 7);
    }
}
