//! Multi-turn conversation sessions for closed-loop serving.
//!
//! The open-loop traces of [`crate::workload::azure`] replay recorded
//! arrivals; real conversational deployments are *closed-loop*: a user
//! submits turn *k+1* only after reading turn *k*'s response, and each
//! follow-up prompt replays the whole prior context (previous prompts +
//! responses) plus some fresh tokens.  That replay is exactly the prefix
//! whose KV can be reused when the follow-up lands on the pair that
//! served the previous turn (see [`crate::cronus::router`]'s
//! `KvAffinity` policy) — the regime HexGen-2 and the multi-vendor
//! disaggregated-serving line of work show dominates heterogeneous
//! cluster scheduling quality.
//!
//! A [`Session`] is a pure, seeded description of one conversation:
//! per-turn fresh-input / output lengths (log-normal, like the Azure
//! marginals) and per-turn think times (exponential).  The closed-loop
//! driver ([`crate::systems::driver::closed_loop`]) materializes each
//! turn into a [`Request`] only when the previous turn has finished and
//! the think time has elapsed, so arrival times are an *output* of the
//! simulation, not an input.

use crate::util::rng::{lognormal_mu_for_mean, Rng};
use crate::workload::Request;

/// Stride between the request ids of consecutive sessions:
/// turn `k` of session `s` gets request id `s * TURN_ID_STRIDE + k`.
/// Deterministic and collision-free for up to 4096 turns per session,
/// so two runs of the same workload produce byte-identical id streams.
pub const TURN_ID_STRIDE: u64 = 1 << 12;

/// Request id of turn `turn` of session `session_id`.
pub fn turn_request_id(session_id: u64, turn: usize) -> u64 {
    debug_assert!((turn as u64) < TURN_ID_STRIDE);
    session_id * TURN_ID_STRIDE + turn as u64
}

/// Session a request id belongs to (inverse of [`turn_request_id`]).
pub fn session_of_request(req_id: u64) -> u64 {
    req_id / TURN_ID_STRIDE
}

/// Generator parameters for a closed-loop session workload.
#[derive(Clone, Copy, Debug)]
pub struct SessionConfig {
    pub n_sessions: usize,
    /// Turns per session, uniform in `[min_turns, max_turns]`.
    pub min_turns: usize,
    pub max_turns: usize,
    /// Mean think time between a turn's finish and the next turn's
    /// submission (exponential distribution).
    pub think_mean_s: f64,
    /// Session start times are uniform in `[0, start_window_s)`.
    pub start_window_s: f64,
    /// Fresh prompt tokens per turn (log-normal, clamped).
    pub mean_new_input: f64,
    pub sigma_new_input: f64,
    pub min_new_input: usize,
    pub max_new_input: usize,
    /// Response tokens per turn (log-normal, clamped).
    pub mean_output: f64,
    pub sigma_output: f64,
    pub min_output: usize,
    pub max_output: usize,
    pub seed: u64,
}

impl Default for SessionConfig {
    fn default() -> Self {
        SessionConfig {
            n_sessions: 32,
            min_turns: 2,
            max_turns: 6,
            think_mean_s: 2.0,
            start_window_s: 10.0,
            mean_new_input: 512.0,
            sigma_new_input: 0.9,
            min_new_input: 16,
            max_new_input: 3072,
            mean_output: 160.0,
            sigma_output: 0.8,
            min_output: 4,
            max_output: 768,
            seed: 42,
        }
    }
}

/// One turn of a conversation, before it is materialized into a
/// [`Request`] by the closed-loop driver.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SessionTurn {
    /// Fresh prompt tokens this turn adds on top of the replayed context.
    pub new_input: usize,
    /// Response tokens this turn generates.
    pub output_len: usize,
    /// Think time between the previous turn's finish and this turn's
    /// submission; 0 for turn 0 (the session starts at
    /// [`Session::start_ns`]).
    pub think_s: f64,
}

/// One seeded conversation.
#[derive(Clone, Debug, PartialEq)]
pub struct Session {
    /// Session id (>= 1; 0 is [`crate::workload::NO_SESSION`]).
    pub id: u64,
    /// Submission instant of turn 0, nanoseconds since experiment start.
    pub start_ns: u64,
    pub turns: Vec<SessionTurn>,
}

impl Session {
    /// Context tokens accumulated before turn `k` — the prompt prefix
    /// turn `k` replays (sum of all earlier turns' fresh inputs and
    /// outputs).  0 for turn 0.
    pub fn prefix_len(&self, k: usize) -> usize {
        self.turns[..k]
            .iter()
            .map(|t| t.new_input + t.output_len)
            .sum()
    }

    /// Full prompt length of turn `k`: replayed prior context plus the
    /// turn's fresh tokens.
    pub fn input_len(&self, k: usize) -> usize {
        self.prefix_len(k) + self.turns[k].new_input
    }

    /// Materialize turn `k` as a [`Request`] arriving at `arrival_ns`.
    /// The id is a deterministic function of (session, turn) so repeated
    /// runs produce identical streams.
    pub fn request(&self, k: usize, arrival_ns: u64) -> Request {
        let turn = &self.turns[k];
        Request {
            id: turn_request_id(self.id, k),
            arrival_ns,
            input_len: self.input_len(k),
            output_len: turn.output_len,
            session_id: self.id,
            prefix_len: self.prefix_len(k),
            kv_credit: 0,
            final_turn: k + 1 == self.turns.len(),
            class: Default::default(),
        }
    }

    /// Sum of all turns' prompt lengths — the prefill tokens a
    /// KV-oblivious system executes when every turn completes.
    pub fn total_input_tokens(&self) -> usize {
        (0..self.turns.len()).map(|k| self.input_len(k)).sum()
    }
}

/// Total turns across a session set.
pub fn total_turns(sessions: &[Session]) -> usize {
    sessions.iter().map(|s| s.turns.len()).sum()
}

/// Generate a seeded session workload.  Deterministic in `cfg.seed`;
/// session ids are `1..=n_sessions` in generation order.
pub fn generate_sessions(cfg: &SessionConfig) -> Vec<Session> {
    assert!(cfg.min_turns >= 1, "sessions need at least one turn");
    assert!(cfg.min_turns <= cfg.max_turns, "min_turns > max_turns");
    assert!(
        (cfg.max_turns as u64) < TURN_ID_STRIDE,
        "max_turns exceeds the request-id stride"
    );
    let mut rng = Rng::new(cfg.seed);
    let mu_in = lognormal_mu_for_mean(cfg.mean_new_input, cfg.sigma_new_input);
    let mu_out = lognormal_mu_for_mean(cfg.mean_output, cfg.sigma_output);
    (0..cfg.n_sessions)
        .map(|s| {
            let start_ns = (rng.f64() * cfg.start_window_s * 1e9).round() as u64;
            let n_turns = rng.range_usize(cfg.min_turns, cfg.max_turns + 1);
            let turns = (0..n_turns)
                .map(|k| SessionTurn {
                    new_input: (rng.lognormal(mu_in, cfg.sigma_new_input).round()
                        as usize)
                        .clamp(cfg.min_new_input, cfg.max_new_input),
                    output_len: (rng.lognormal(mu_out, cfg.sigma_output).round()
                        as usize)
                        .clamp(cfg.min_output, cfg.max_output),
                    think_s: if k == 0 {
                        0.0
                    } else {
                        rng.exponential(1.0 / cfg.think_mean_s.max(1e-9))
                    },
                })
                .collect();
            Session { id: s as u64 + 1, start_ns, turns }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::NO_SESSION;

    #[test]
    fn deterministic_in_seed() {
        let cfg = SessionConfig::default();
        let a = generate_sessions(&cfg);
        let b = generate_sessions(&cfg);
        assert_eq!(a, b);
        let c = generate_sessions(&SessionConfig { seed: 43, ..cfg });
        assert_ne!(a, c);
    }

    #[test]
    fn prefix_is_prior_context() {
        let cfg = SessionConfig { n_sessions: 4, seed: 7, ..Default::default() };
        for s in generate_sessions(&cfg) {
            assert!(s.id > NO_SESSION);
            let mut ctx = 0usize;
            for k in 0..s.turns.len() {
                assert_eq!(s.prefix_len(k), ctx);
                assert_eq!(s.input_len(k), ctx + s.turns[k].new_input);
                let req = s.request(k, 123);
                assert_eq!(req.session_id, s.id);
                assert_eq!(req.prefix_len, ctx);
                assert_eq!(req.fresh_input(), s.turns[k].new_input);
                assert!(req.prefix_len < req.input_len, "turn adds fresh tokens");
                assert_eq!(req.final_turn, k + 1 == s.turns.len());
                assert_eq!(req.kv_credit, 0);
                assert_eq!(session_of_request(req.id), s.id);
                ctx += s.turns[k].new_input + s.turns[k].output_len;
            }
            assert_eq!(
                s.total_input_tokens(),
                (0..s.turns.len()).map(|k| s.input_len(k)).sum::<usize>()
            );
        }
    }

    #[test]
    fn turn_counts_and_clamps_respected() {
        let cfg = SessionConfig {
            n_sessions: 50,
            min_turns: 2,
            max_turns: 5,
            seed: 11,
            ..Default::default()
        };
        let sessions = generate_sessions(&cfg);
        assert_eq!(sessions.len(), 50);
        for s in &sessions {
            assert!((2..=5).contains(&s.turns.len()));
            assert!(s.start_ns <= (cfg.start_window_s * 1e9) as u64);
            for (k, t) in s.turns.iter().enumerate() {
                assert!((cfg.min_new_input..=cfg.max_new_input).contains(&t.new_input));
                assert!((cfg.min_output..=cfg.max_output).contains(&t.output_len));
                if k == 0 {
                    assert_eq!(t.think_s, 0.0);
                } else {
                    assert!(t.think_s > 0.0);
                }
            }
        }
        // Ids are unique across all turns of all sessions.
        let mut ids: Vec<u64> = sessions
            .iter()
            .flat_map(|s| (0..s.turns.len()).map(|k| turn_request_id(s.id, k)))
            .collect();
        let n = ids.len();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), n);
    }

    #[test]
    fn think_times_match_mean_roughly() {
        let cfg = SessionConfig {
            n_sessions: 400,
            min_turns: 8,
            max_turns: 8,
            think_mean_s: 3.0,
            seed: 5,
            ..Default::default()
        };
        let sessions = generate_sessions(&cfg);
        let thinks: Vec<f64> = sessions
            .iter()
            .flat_map(|s| s.turns.iter().skip(1).map(|t| t.think_s))
            .collect();
        let mean = thinks.iter().sum::<f64>() / thinks.len() as f64;
        assert!((mean - 3.0).abs() < 0.3, "think mean {mean}");
    }
}
