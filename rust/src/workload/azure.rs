//! Synthetic Azure-2023-like conversation trace generator.
//!
//! Substitution for the Microsoft Azure LLM inference trace (2023) used
//! by the paper (via Splitwise): we match the published marginal
//! statistics of the conversation subset the paper reports — mean input
//! 1014 tokens, mean output 247 tokens — with the long-tailed log-normal
//! shapes characteristic of conversation workloads, clipped to the
//! serving window.  The schedulers only ever consume
//! `(input_len, output_len, arrival)` triples, so matching these
//! marginals reproduces the load structure the experiments depend on.

use crate::util::rng::{lognormal_mu_for_mean, Rng};
use crate::workload::Request;

/// Generator parameters (defaults = the paper's conversation trace).
#[derive(Clone, Copy, Debug)]
pub struct AzureTraceConfig {
    pub mean_input: f64,
    pub mean_output: f64,
    /// Log-normal shape parameters (tail heaviness).
    pub sigma_input: f64,
    pub sigma_output: f64,
    pub min_input: usize,
    pub max_input: usize,
    pub min_output: usize,
    pub max_output: usize,
}

impl Default for AzureTraceConfig {
    fn default() -> Self {
        AzureTraceConfig {
            mean_input: 1014.0,
            mean_output: 247.0,
            sigma_input: 0.9,
            sigma_output: 0.8,
            min_input: 16,
            max_input: 8192,
            min_output: 4,
            max_output: 2048,
        }
    }
}

impl AzureTraceConfig {
    /// The §6 limitation workload: short inputs, long outputs (decode-
    /// dominated) — used by the `ablation_limits` bench.
    pub fn short_input_long_output() -> Self {
        AzureTraceConfig {
            mean_input: 128.0,
            mean_output: 512.0,
            sigma_input: 0.5,
            sigma_output: 0.6,
            min_input: 8,
            max_input: 1024,
            min_output: 32,
            max_output: 4096,
        }
    }
}

/// Generate `n` requests with arrival_ns = 0 (callers stamp arrivals via
/// [`crate::workload::arrival`]).  Deterministic in `seed`.
pub fn generate(n: usize, cfg: &AzureTraceConfig, seed: u64) -> Vec<Request> {
    let mut rng = Rng::new(seed);
    let mu_in = lognormal_mu_for_mean(cfg.mean_input, cfg.sigma_input);
    let mu_out = lognormal_mu_for_mean(cfg.mean_output, cfg.sigma_output);
    (0..n)
        .map(|i| {
            let input_len = (rng.lognormal(mu_in, cfg.sigma_input).round()
                as usize)
                .clamp(cfg.min_input, cfg.max_input);
            let output_len = (rng.lognormal(mu_out, cfg.sigma_output).round()
                as usize)
                .clamp(cfg.min_output, cfg.max_output);
            Request::new(i as u64, 0, input_len, output_len)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::stats;

    #[test]
    fn matches_paper_means() {
        let trace = generate(20_000, &AzureTraceConfig::default(), 42);
        let s = stats(&trace);
        // Clipping pulls the mean slightly below the raw log-normal's.
        assert!(
            (s.mean_input - 1014.0).abs() / 1014.0 < 0.08,
            "mean input {}",
            s.mean_input
        );
        assert!(
            (s.mean_output - 247.0).abs() / 247.0 < 0.08,
            "mean output {}",
            s.mean_output
        );
    }

    #[test]
    fn long_tail_exists_but_clipped() {
        let trace = generate(20_000, &AzureTraceConfig::default(), 7);
        let s = stats(&trace);
        assert!(s.max_input > 4000, "no tail: max input {}", s.max_input);
        assert!(s.max_input <= 8192);
        assert!(s.max_output <= 2048);
        assert!(trace.iter().all(|r| r.input_len >= 16 && r.output_len >= 4));
    }

    #[test]
    fn deterministic_in_seed() {
        let a = generate(100, &AzureTraceConfig::default(), 5);
        let b = generate(100, &AzureTraceConfig::default(), 5);
        assert_eq!(a, b);
        let c = generate(100, &AzureTraceConfig::default(), 6);
        assert_ne!(a, c);
    }

    #[test]
    fn ids_are_sequential() {
        let trace = generate(10, &AzureTraceConfig::default(), 1);
        for (i, r) in trace.iter().enumerate() {
            assert_eq!(r.id, i as u64);
        }
    }

    #[test]
    fn short_in_long_out_flips_ratio() {
        let trace = generate(5_000, &AzureTraceConfig::short_input_long_output(), 3);
        let s = stats(&trace);
        assert!(s.mean_output > 2.0 * s.mean_input, "{s:?}");
    }
}
