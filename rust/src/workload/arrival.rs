//! Arrival processes: stamp `arrival_ns` onto a generated trace.
//!
//! The paper sends requests "with fixed time interval" for the latency
//! experiments (Fig. 4) and all-at-once for max throughput (Table 2).
//! Poisson arrivals are provided for ablations, and two
//! production-shaped processes drive the scaled chaos runs:
//!
//! * [`ArrivalProcess::Diurnal`] — a non-homogeneous Poisson process
//!   whose rate follows a raised-cosine day/night curve between
//!   `trough_rps` and `peak_rps` with period `period_s`, sampled by
//!   thinning (candidate arrivals at the peak rate, accepted with
//!   probability `rate(t) / peak`).
//! * [`ArrivalProcess::Bursty`] — a two-state Markov-modulated Poisson
//!   process: quiet epochs at `base_rps` alternate with burst epochs at
//!   `burst_rps`; burst durations are exponential with mean
//!   `burst_len_s`, quiet gaps exponential with mean
//!   [`QUIET_GAP_FACTOR`]` × burst_len_s` (a 20 % burst duty cycle).
//!
//! Every rate-bearing variant is validated: construct processes through
//! the checked constructors ([`ArrivalProcess::poisson`] and friends),
//! which reject non-finite or non-positive rates with a typed
//! [`ArrivalError`] instead of looping forever or stamping NaN
//! timestamps.  [`stamp`] re-validates and panics with the same message
//! on a hand-built invalid variant.

use std::fmt;

use crate::util::rng::Rng;
use crate::workload::Request;

/// Mean quiet-gap length of [`ArrivalProcess::Bursty`], as a multiple of
/// `burst_len_s`: gaps average 4× the burst length, so bursts occupy
/// ~20 % of the timeline.
pub const QUIET_GAP_FACTOR: f64 = 4.0;

#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ArrivalProcess {
    /// Everything arrives at t=0 (max-throughput measurement).
    AllAtOnce,
    /// One request every `interval_s` seconds (the paper's Fig. 4 load).
    FixedInterval { interval_s: f64 },
    /// Poisson process with `rate_rps` requests/second.
    Poisson { rate_rps: f64, seed: u64 },
    /// Non-homogeneous Poisson with a raised-cosine diurnal rate curve:
    /// `rate(t) = trough + (peak − trough) · (1 − cos(2πt/period)) / 2`
    /// (trough at t=0, peak at t=period/2), sampled by thinning.
    Diurnal { period_s: f64, peak_rps: f64, trough_rps: f64, seed: u64 },
    /// Two-state MMPP: `base_rps` in quiet epochs, `burst_rps` during
    /// bursts whose durations average `burst_len_s` seconds.
    Bursty { base_rps: f64, burst_rps: f64, burst_len_s: f64, seed: u64 },
}

/// Why an arrival process was rejected at construction.
#[derive(Clone, Debug, PartialEq)]
pub enum ArrivalError {
    /// A rate or duration parameter was non-finite, or outside its legal
    /// range (rates must be positive where arrivals depend on them).
    BadRate {
        process: &'static str,
        field: &'static str,
        value: f64,
    },
    /// Parameters are individually finite but mutually inconsistent
    /// (e.g. a diurnal trough above its peak).
    BadShape { process: &'static str, why: String },
}

impl fmt::Display for ArrivalError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ArrivalError::BadRate { process, field, value } => write!(
                f,
                "invalid {process} arrival process: {field} = {value} \
                 (must be finite and in range)"
            ),
            ArrivalError::BadShape { process, why } => {
                write!(f, "invalid {process} arrival process: {why}")
            }
        }
    }
}

impl std::error::Error for ArrivalError {}

/// `value` must be finite and `> 0`.
fn positive(
    process: &'static str,
    field: &'static str,
    value: f64,
) -> Result<(), ArrivalError> {
    if value.is_finite() && value > 0.0 {
        Ok(())
    } else {
        Err(ArrivalError::BadRate { process, field, value })
    }
}

/// `value` must be finite and `>= 0`.
fn non_negative(
    process: &'static str,
    field: &'static str,
    value: f64,
) -> Result<(), ArrivalError> {
    if value.is_finite() && value >= 0.0 {
        Ok(())
    } else {
        Err(ArrivalError::BadRate { process, field, value })
    }
}

impl ArrivalProcess {
    /// Checked constructor for [`ArrivalProcess::FixedInterval`].
    pub fn fixed(interval_s: f64) -> Result<ArrivalProcess, ArrivalError> {
        let p = ArrivalProcess::FixedInterval { interval_s };
        p.validate()?;
        Ok(p)
    }

    /// Checked constructor for [`ArrivalProcess::Poisson`].
    pub fn poisson(rate_rps: f64, seed: u64) -> Result<ArrivalProcess, ArrivalError> {
        let p = ArrivalProcess::Poisson { rate_rps, seed };
        p.validate()?;
        Ok(p)
    }

    /// Checked constructor for [`ArrivalProcess::Diurnal`].
    pub fn diurnal(
        period_s: f64,
        peak_rps: f64,
        trough_rps: f64,
        seed: u64,
    ) -> Result<ArrivalProcess, ArrivalError> {
        let p = ArrivalProcess::Diurnal { period_s, peak_rps, trough_rps, seed };
        p.validate()?;
        Ok(p)
    }

    /// Checked constructor for [`ArrivalProcess::Bursty`].
    pub fn bursty(
        base_rps: f64,
        burst_rps: f64,
        burst_len_s: f64,
        seed: u64,
    ) -> Result<ArrivalProcess, ArrivalError> {
        let p = ArrivalProcess::Bursty { base_rps, burst_rps, burst_len_s, seed };
        p.validate()?;
        Ok(p)
    }

    /// Validate this process's parameters — the single source of truth
    /// behind the checked constructors, [`stamp`], and the scenario
    /// capsule loader.
    pub fn validate(&self) -> Result<(), ArrivalError> {
        match *self {
            ArrivalProcess::AllAtOnce => Ok(()),
            ArrivalProcess::FixedInterval { interval_s } => {
                non_negative("fixed-interval", "interval_s", interval_s)
            }
            ArrivalProcess::Poisson { rate_rps, .. } => {
                positive("poisson", "rate_rps", rate_rps)
            }
            ArrivalProcess::Diurnal { period_s, peak_rps, trough_rps, .. } => {
                positive("diurnal", "period_s", period_s)?;
                positive("diurnal", "peak_rps", peak_rps)?;
                non_negative("diurnal", "trough_rps", trough_rps)?;
                if trough_rps > peak_rps {
                    return Err(ArrivalError::BadShape {
                        process: "diurnal",
                        why: format!(
                            "trough_rps {trough_rps} exceeds peak_rps {peak_rps}"
                        ),
                    });
                }
                Ok(())
            }
            ArrivalProcess::Bursty { base_rps, burst_rps, burst_len_s, .. } => {
                non_negative("bursty", "base_rps", base_rps)?;
                positive("bursty", "burst_rps", burst_rps)?;
                positive("bursty", "burst_len_s", burst_len_s)?;
                if base_rps > burst_rps {
                    return Err(ArrivalError::BadShape {
                        process: "bursty",
                        why: format!(
                            "base_rps {base_rps} exceeds burst_rps {burst_rps}"
                        ),
                    });
                }
                Ok(())
            }
        }
    }
}

/// Instantaneous diurnal rate at time `t` (seconds).
fn diurnal_rate(t: f64, period_s: f64, peak_rps: f64, trough_rps: f64) -> f64 {
    let phase = (std::f64::consts::TAU * t / period_s).cos();
    trough_rps + (peak_rps - trough_rps) * (1.0 - phase) * 0.5
}

/// Return a copy of `trace` with arrival times stamped.
///
/// Panics on an invalid process (see [`ArrivalProcess::validate`]); use
/// the checked constructors to surface the error as a value instead.
pub fn stamp(trace: &[Request], process: ArrivalProcess) -> Vec<Request> {
    if let Err(e) = process.validate() {
        panic!("stamp: {e}");
    }
    let mut out = trace.to_vec();
    match process {
        ArrivalProcess::AllAtOnce => {
            for r in &mut out {
                r.arrival_ns = 0;
            }
        }
        ArrivalProcess::FixedInterval { interval_s } => {
            for (i, r) in out.iter_mut().enumerate() {
                r.arrival_ns = (i as f64 * interval_s * 1e9).round() as u64;
            }
        }
        ArrivalProcess::Poisson { rate_rps, seed } => {
            let mut rng = Rng::new(seed);
            let mut t = 0.0f64;
            for r in &mut out {
                t += rng.exponential(rate_rps);
                r.arrival_ns = (t * 1e9).round() as u64;
            }
        }
        ArrivalProcess::Diurnal { period_s, peak_rps, trough_rps, seed } => {
            // Thinning (Lewis–Shedler): homogeneous candidates at the
            // peak rate, accepted with probability rate(t)/peak.
            // Rejected candidates still advance t, so the loop always
            // terminates even through a zero-rate trough.
            let mut rng = Rng::new(seed);
            let mut t = 0.0f64;
            for r in &mut out {
                loop {
                    t += rng.exponential(peak_rps);
                    let rate = diurnal_rate(t, period_s, peak_rps, trough_rps);
                    if rng.f64() * peak_rps <= rate {
                        break;
                    }
                }
                r.arrival_ns = (t * 1e9).round() as u64;
            }
        }
        ArrivalProcess::Bursty { base_rps, burst_rps, burst_len_s, seed } => {
            // Two-state MMPP.  The exponential clock is memoryless, so
            // re-sampling the inter-arrival gap after a state switch is
            // distribution-exact.
            let mut rng = Rng::new(seed);
            let mut t = 0.0f64;
            let mut in_burst = false;
            let mut state_end = rng.exponential(1.0 / (QUIET_GAP_FACTOR * burst_len_s));
            for r in &mut out {
                loop {
                    let rate = if in_burst { burst_rps } else { base_rps };
                    let dt =
                        if rate > 0.0 { rng.exponential(rate) } else { f64::INFINITY };
                    if t + dt <= state_end {
                        t += dt;
                        break;
                    }
                    t = state_end;
                    in_burst = !in_burst;
                    let mean_len = if in_burst {
                        burst_len_s
                    } else {
                        QUIET_GAP_FACTOR * burst_len_s
                    };
                    state_end = t + rng.exponential(1.0 / mean_len);
                }
                r.arrival_ns = (t * 1e9).round() as u64;
            }
        }
    }
    out
}

/// Convenience: fixed-interval arrivals at a target rate in requests/s.
pub fn at_rate(trace: &[Request], rate_rps: f64) -> Vec<Request> {
    stamp(trace, ArrivalProcess::FixedInterval { interval_s: 1.0 / rate_rps })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk(n: usize) -> Vec<Request> {
        (0..n).map(|i| Request::new(i as u64, 999, 10, 5)).collect()
    }

    #[test]
    fn all_at_once_zeroes() {
        let out = stamp(&mk(5), ArrivalProcess::AllAtOnce);
        assert!(out.iter().all(|r| r.arrival_ns == 0));
    }

    #[test]
    fn fixed_interval_spacing() {
        let out = stamp(&mk(4), ArrivalProcess::FixedInterval { interval_s: 0.25 });
        let times: Vec<u64> = out.iter().map(|r| r.arrival_ns).collect();
        assert_eq!(times, vec![0, 250_000_000, 500_000_000, 750_000_000]);
    }

    #[test]
    fn at_rate_matches_interval() {
        let out = at_rate(&mk(3), 4.0);
        assert_eq!(out[1].arrival_ns, 250_000_000);
    }

    #[test]
    fn poisson_mean_rate() {
        let out = stamp(&mk(20_000), ArrivalProcess::Poisson { rate_rps: 8.0, seed: 1 });
        let span_s = out.last().unwrap().arrival_ns as f64 / 1e9;
        let rate = 20_000.0 / span_s;
        assert!((rate - 8.0).abs() < 0.3, "rate {rate}");
        // Strictly increasing.
        assert!(out.windows(2).all(|w| w[0].arrival_ns < w[1].arrival_ns));
    }

    #[test]
    fn stamp_preserves_payload() {
        let out = stamp(&mk(3), ArrivalProcess::AllAtOnce);
        assert!(out.iter().all(|r| r.input_len == 10 && r.output_len == 5));
        assert_eq!(out.len(), 3);
    }

    // --- validation (typed errors at construction) ---

    #[test]
    fn bad_rates_are_rejected_with_typed_errors() {
        for bad in [0.0, -3.0, f64::NAN, f64::INFINITY] {
            let e = ArrivalProcess::poisson(bad, 1).unwrap_err();
            assert!(
                matches!(e, ArrivalError::BadRate { field: "rate_rps", .. }),
                "{e}"
            );
        }
        assert!(ArrivalProcess::fixed(-0.1).is_err());
        assert!(ArrivalProcess::fixed(f64::NAN).is_err());
        assert!(ArrivalProcess::fixed(0.0).is_ok()); // degenerate but legal

        assert!(ArrivalProcess::diurnal(0.0, 10.0, 1.0, 1).is_err());
        assert!(ArrivalProcess::diurnal(10.0, 0.0, 0.0, 1).is_err());
        assert!(ArrivalProcess::diurnal(10.0, f64::NAN, 0.0, 1).is_err());
        assert!(ArrivalProcess::diurnal(10.0, 4.0, -1.0, 1).is_err());
        // Trough above peak is a shape error, not a rate error.
        let e = ArrivalProcess::diurnal(10.0, 4.0, 8.0, 1).unwrap_err();
        assert!(matches!(e, ArrivalError::BadShape { .. }), "{e}");

        assert!(ArrivalProcess::bursty(1.0, 0.0, 1.0, 1).is_err());
        assert!(ArrivalProcess::bursty(-1.0, 10.0, 1.0, 1).is_err());
        assert!(ArrivalProcess::bursty(1.0, 10.0, 0.0, 1).is_err());
        assert!(ArrivalProcess::bursty(1.0, 10.0, f64::INFINITY, 1).is_err());
        let e = ArrivalProcess::bursty(20.0, 10.0, 1.0, 1).unwrap_err();
        assert!(matches!(e, ArrivalError::BadShape { .. }), "{e}");
        // Zero base rate is fine: all traffic arrives in bursts.
        assert!(ArrivalProcess::bursty(0.0, 10.0, 1.0, 1).is_ok());

        // The error renders a human-readable message.
        let msg = ArrivalProcess::poisson(-1.0, 0).unwrap_err().to_string();
        assert!(msg.contains("rate_rps"), "{msg}");
    }

    #[test]
    #[should_panic(expected = "stamp: invalid poisson arrival process")]
    fn stamp_panics_on_hand_built_invalid_process() {
        stamp(&mk(2), ArrivalProcess::Poisson { rate_rps: 0.0, seed: 1 });
    }

    #[test]
    fn diurnal_mean_rate_and_shape() {
        let p = ArrivalProcess::diurnal(10.0, 16.0, 4.0, 7).unwrap();
        let out = stamp(&mk(20_000), p);
        assert!(out.windows(2).all(|w| w[0].arrival_ns <= w[1].arrival_ns));
        let span_s = out.last().unwrap().arrival_ns as f64 / 1e9;
        // Long-run mean rate is (peak + trough) / 2 = 10 rps.
        let rate = 20_000.0 / span_s;
        assert!((rate - 10.0).abs() < 1.0, "mean rate {rate}");
        // Arrivals concentrate around the peak phase (period/2): the
        // middle half of each period carries more than half the load.
        let mid = out
            .iter()
            .filter(|r| {
                let phase = (r.arrival_ns as f64 / 1e9) % 10.0;
                (2.5..7.5).contains(&phase)
            })
            .count();
        assert!(
            mid as f64 > 0.55 * out.len() as f64,
            "only {mid}/{} arrivals near the diurnal peak",
            out.len()
        );
    }

    #[test]
    fn bursty_clusters_arrivals() {
        let p = ArrivalProcess::bursty(1.0, 50.0, 1.0, 5).unwrap();
        let out = stamp(&mk(10_000), p);
        assert!(out.windows(2).all(|w| w[0].arrival_ns <= w[1].arrival_ns));
        let span_s = out.last().unwrap().arrival_ns as f64 / 1e9;
        // Long-run mean ≈ (4·base + 1·burst) / 5 = 10.8 rps; loose band.
        let rate = 10_000.0 / span_s;
        assert!((2.0..40.0).contains(&rate), "mean rate {rate}");
        // Clustering: the busiest 1-second window far exceeds the mean.
        let mut per_sec = std::collections::HashMap::new();
        for r in &out {
            *per_sec.entry(r.arrival_ns / 1_000_000_000).or_insert(0u32) += 1;
        }
        let peak = per_sec.values().copied().max().unwrap();
        assert!(peak as f64 > 2.0 * rate, "peak window {peak} vs mean {rate}");
    }

    #[test]
    fn new_processes_are_seed_deterministic() {
        for p in [
            ArrivalProcess::diurnal(10.0, 16.0, 4.0, 11).unwrap(),
            ArrivalProcess::bursty(1.0, 30.0, 2.0, 11).unwrap(),
        ] {
            let a = stamp(&mk(500), p);
            let b = stamp(&mk(500), p);
            assert!(a
                .iter()
                .zip(&b)
                .all(|(x, y)| x.arrival_ns == y.arrival_ns));
        }
        let a = stamp(&mk(500), ArrivalProcess::diurnal(10.0, 16.0, 4.0, 1).unwrap());
        let b = stamp(&mk(500), ArrivalProcess::diurnal(10.0, 16.0, 4.0, 2).unwrap());
        assert!(a.iter().zip(&b).any(|(x, y)| x.arrival_ns != y.arrival_ns));
    }
}
