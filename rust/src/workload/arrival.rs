//! Arrival processes: stamp `arrival_ns` onto a generated trace.
//!
//! The paper sends requests "with fixed time interval" for the latency
//! experiments (Fig. 4) and all-at-once for max throughput (Table 2).
//! Poisson arrivals are provided for ablations.

use crate::util::rng::Rng;
use crate::workload::Request;

#[derive(Clone, Copy, Debug)]
pub enum ArrivalProcess {
    /// Everything arrives at t=0 (max-throughput measurement).
    AllAtOnce,
    /// One request every `interval_s` seconds (the paper's Fig. 4 load).
    FixedInterval { interval_s: f64 },
    /// Poisson process with `rate_rps` requests/second.
    Poisson { rate_rps: f64, seed: u64 },
}

/// Return a copy of `trace` with arrival times stamped.
pub fn stamp(trace: &[Request], process: ArrivalProcess) -> Vec<Request> {
    let mut out = trace.to_vec();
    match process {
        ArrivalProcess::AllAtOnce => {
            for r in &mut out {
                r.arrival_ns = 0;
            }
        }
        ArrivalProcess::FixedInterval { interval_s } => {
            assert!(interval_s >= 0.0);
            for (i, r) in out.iter_mut().enumerate() {
                r.arrival_ns = (i as f64 * interval_s * 1e9).round() as u64;
            }
        }
        ArrivalProcess::Poisson { rate_rps, seed } => {
            assert!(rate_rps > 0.0);
            let mut rng = Rng::new(seed);
            let mut t = 0.0f64;
            for r in &mut out {
                t += rng.exponential(rate_rps);
                r.arrival_ns = (t * 1e9).round() as u64;
            }
        }
    }
    out
}

/// Convenience: fixed-interval arrivals at a target rate in requests/s.
pub fn at_rate(trace: &[Request], rate_rps: f64) -> Vec<Request> {
    stamp(trace, ArrivalProcess::FixedInterval { interval_s: 1.0 / rate_rps })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk(n: usize) -> Vec<Request> {
        (0..n).map(|i| Request::new(i as u64, 999, 10, 5)).collect()
    }

    #[test]
    fn all_at_once_zeroes() {
        let out = stamp(&mk(5), ArrivalProcess::AllAtOnce);
        assert!(out.iter().all(|r| r.arrival_ns == 0));
    }

    #[test]
    fn fixed_interval_spacing() {
        let out = stamp(&mk(4), ArrivalProcess::FixedInterval { interval_s: 0.25 });
        let times: Vec<u64> = out.iter().map(|r| r.arrival_ns).collect();
        assert_eq!(times, vec![0, 250_000_000, 500_000_000, 750_000_000]);
    }

    #[test]
    fn at_rate_matches_interval() {
        let out = at_rate(&mk(3), 4.0);
        assert_eq!(out[1].arrival_ns, 250_000_000);
    }

    #[test]
    fn poisson_mean_rate() {
        let out = stamp(&mk(20_000), ArrivalProcess::Poisson { rate_rps: 8.0, seed: 1 });
        let span_s = out.last().unwrap().arrival_ns as f64 / 1e9;
        let rate = 20_000.0 / span_s;
        assert!((rate - 8.0).abs() < 0.3, "rate {rate}");
        // Strictly increasing.
        assert!(out.windows(2).all(|w| w[0].arrival_ns < w[1].arrival_ns));
    }

    #[test]
    fn stamp_preserves_payload() {
        let out = stamp(&mk(3), ArrivalProcess::AllAtOnce);
        assert!(out.iter().all(|r| r.input_len == 10 && r.output_len == 5));
        assert_eq!(out.len(), 3);
    }
}
