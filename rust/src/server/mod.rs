//! Real-model serving front: a threaded server that drives the AOT
//! tiny-LLaMA through [`crate::runtime::TokenModel`] with the same
//! chunked-prefill-plus-batched-decode iteration structure the simulated
//! engines use.  This is what proves the three layers compose: Rust
//! coordination, PJRT-executed JAX model, Pallas attention cores — with
//! Python nowhere on the request path.
//!
//! Used by `examples/serve_trace.rs` (the end-to-end driver) and
//! `cronus serve`.

use std::collections::VecDeque;
use std::path::Path;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::thread::JoinHandle;
use std::time::Instant;

use crate::runtime::{KvState, TokenModel};
use crate::util::error::Result;

/// A request to the real-model server.
#[derive(Clone, Debug)]
pub struct ServeRequest {
    pub id: u64,
    pub prompt: Vec<i32>,
    pub max_new_tokens: usize,
}

/// A served response with wall-clock latency breakdown.
#[derive(Clone, Debug)]
pub struct ServeResponse {
    pub id: u64,
    pub tokens: Vec<i32>,
    /// Wall-clock time from submission to first token.
    pub ttft_s: f64,
    /// Wall-clock gaps between subsequent tokens.
    pub tbt_s: Vec<f64>,
}

enum Msg {
    Request(ServeRequest, Instant),
    Shutdown,
}

struct Active {
    id: u64,
    prompt: Vec<i32>,
    submitted: Instant,
    kv: KvState,
    prefilled: usize,
    generated: Vec<i32>,
    max_new_tokens: usize,
    first_token_at: Option<Instant>,
    last_token_at: Option<Instant>,
    gaps: Vec<f64>,
}

/// Threaded serving front over the real tiny model.
pub struct RealServer {
    tx: Sender<Msg>,
    rx: Receiver<ServeResponse>,
    handle: Option<JoinHandle<Result<()>>>,
}

impl RealServer {
    /// Load artifacts and start the worker thread.
    pub fn start(artifacts_dir: &Path) -> Result<RealServer> {
        let (tx, worker_rx) = channel::<Msg>();
        let (resp_tx, rx) = channel::<ServeResponse>();
        let dir = artifacts_dir.to_path_buf();
        let handle = std::thread::Builder::new()
            .name("cronus-serve".into())
            .spawn(move || worker(&dir, worker_rx, resp_tx))?;
        Ok(RealServer { tx, rx, handle: Some(handle) })
    }

    pub fn submit(&self, req: ServeRequest) {
        let _ = self.tx.send(Msg::Request(req, Instant::now()));
    }

    /// Close the request stream, drain all responses, join the worker.
    pub fn shutdown(mut self) -> Result<Vec<ServeResponse>> {
        let _ = self.tx.send(Msg::Shutdown);
        let mut out = Vec::new();
        while let Ok(resp) = self.rx.recv() {
            out.push(resp);
        }
        if let Some(h) = self.handle.take() {
            h.join().expect("server thread panicked")?;
        }
        Ok(out)
    }
}

/// The iteration loop: mirrors the engine's policy at miniature scale —
/// run pending prefill chunk(s) for the head-of-line request, then one
/// batched decode step for everything decoding.
fn worker(
    dir: &Path,
    rx: Receiver<Msg>,
    resp: Sender<ServeResponse>,
) -> Result<()> {
    let model = TokenModel::load(dir)?;
    let chunk = model.chunk_size();
    let batch = model.decode_batch_size();
    let max_seq = model.manifest.max_seq;

    let mut waiting: VecDeque<Active> = VecDeque::new();
    let mut active: Vec<Active> = Vec::new();
    let mut open = true;

    loop {
        // Pull in new requests (blocking only when fully idle).
        loop {
            let msg = if open && waiting.is_empty() && active.is_empty() {
                match rx.recv() {
                    Ok(m) => m,
                    Err(_) => return Ok(()),
                }
            } else {
                match rx.try_recv() {
                    Ok(m) => m,
                    Err(_) => break,
                }
            };
            match msg {
                Msg::Shutdown => {
                    open = false;
                    if waiting.is_empty() && active.is_empty() {
                        return Ok(());
                    }
                }
                Msg::Request(r, at) => {
                    let mut prompt = r.prompt;
                    prompt.truncate(max_seq.saturating_sub(r.max_new_tokens + 1));
                    if prompt.is_empty() {
                        prompt.push(0);
                    }
                    waiting.push_back(Active {
                        id: r.id,
                        prompt,
                        submitted: at,
                        kv: KvState::new(&model.manifest),
                        prefilled: 0,
                        generated: Vec::new(),
                        max_new_tokens: r.max_new_tokens.max(1),
                        first_token_at: None,
                        last_token_at: None,
                        gaps: Vec::new(),
                    });
                }
            }
        }

        // Admit up to the decode batch width.
        while active.len() < batch && !waiting.is_empty() {
            active.push(waiting.pop_front().unwrap());
        }
        if active.is_empty() {
            if !open {
                return Ok(());
            }
            continue;
        }

        // One prefill chunk for the first still-prefilling request.
        if let Some(a) = active.iter_mut().find(|a| a.prefilled < a.prompt.len()) {
            let start = a.prefilled;
            let end = (start + chunk).min(a.prompt.len());
            let logits =
                model.prefill_chunk(&a.prompt[start..end], start, &mut a.kv)?;
            a.prefilled = end;
            if a.prefilled == a.prompt.len() {
                let tok = TokenModel::argmax(&logits);
                let now = Instant::now();
                a.first_token_at = Some(now);
                a.last_token_at = Some(now);
                a.generated.push(tok);
            }
            continue; // alternate prefill/decode iterations
        }

        // Batched decode step for all active (fully prefilled) requests.
        {
            let mut entries: Vec<(i32, usize, &mut KvState)> = Vec::new();
            let mut idxs: Vec<usize> = Vec::new();
            // Split borrows: collect (token, pos) first.
            let toks_pos: Vec<(i32, usize)> = active
                .iter()
                .map(|a| {
                    let last = *a.generated.last().unwrap();
                    (last, a.prompt.len() + a.generated.len() - 1)
                })
                .collect();
            for (i, a) in active.iter_mut().enumerate() {
                let (tok, pos) = toks_pos[i];
                if pos + 1 >= max_seq {
                    continue; // out of cache; will be finalized below
                }
                entries.push((tok, pos, &mut a.kv));
                idxs.push(i);
            }
            if !entries.is_empty() {
                let logits = model.decode_batch(&mut entries)?;
                let now = Instant::now();
                for (slot, row) in idxs.iter().zip(logits) {
                    let a = &mut active[*slot];
                    let tok = TokenModel::argmax(&row);
                    if let Some(prev) = a.last_token_at {
                        a.gaps.push(now.duration_since(prev).as_secs_f64());
                    }
                    a.last_token_at = Some(now);
                    a.generated.push(tok);
                }
            }
        }

        // Retire finished requests.
        let mut i = 0;
        while i < active.len() {
            let done = active[i].generated.len() >= active[i].max_new_tokens
                || active[i].prompt.len() + active[i].generated.len()
                    >= max_seq - 1;
            if done {
                let a = active.swap_remove(i);
                let ttft = a
                    .first_token_at
                    .map(|t| t.duration_since(a.submitted).as_secs_f64())
                    .unwrap_or(0.0);
                let _ = resp.send(ServeResponse {
                    id: a.id,
                    tokens: a.generated,
                    ttft_s: ttft,
                    tbt_s: a.gaps,
                });
            } else {
                i += 1;
            }
        }
    }
}
