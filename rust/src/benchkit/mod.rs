//! Minimal benchmark harness (criterion is unavailable offline).
//!
//! Three roles:
//! * wall-clock micro-benchmarks of the coordinator hot paths
//!   ([`bench_fn`]) with warmup, repetitions and basic statistics;
//! * experiment table formatting shared by the paper-reproduction
//!   benches ([`Table`]);
//! * machine-readable result emission ([`JVal`]) — the perf-regression
//!   harness (`benches/perf_hotpath.rs`) serializes its results to
//!   `BENCH_hotpath.json` with a schema-stable layout that CI archives
//!   (see EXPERIMENTS.md §Perf).

use std::time::Instant;

use crate::util::stats;

/// Result of a micro-benchmark.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p99_ns: f64,
}

impl BenchResult {
    pub fn summary(&self) -> String {
        format!(
            "{:<40} {:>10.0} ns/iter (p50 {:>10.0}, p99 {:>10.0}, n={})",
            self.name, self.mean_ns, self.p50_ns, self.p99_ns, self.iters
        )
    }
}

/// Time `f` with warmup; samples are per-call durations.
pub fn bench_fn<F: FnMut()>(name: &str, warmup: u32, iters: u32, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters as usize);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_nanos() as f64);
    }
    BenchResult {
        name: name.to_string(),
        iters: iters as u64,
        mean_ns: stats::mean(&samples),
        p50_ns: stats::percentile(&samples, 50.0),
        p99_ns: stats::percentile(&samples, 99.0),
    }
}

/// Measure a single long-running closure's wall time in seconds.
pub fn time_once<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed().as_secs_f64())
}

/// Minimal JSON value serializer — the writing counterpart of
/// [`crate::util::json`] (which only parses).  Just enough for the bench
/// artifacts: objects keep insertion order so the emitted schema is
/// stable and diffable across runs.
#[derive(Clone, Debug)]
pub enum JVal {
    Num(f64),
    Int(u64),
    Str(String),
    Bool(bool),
    Arr(Vec<JVal>),
    Obj(Vec<(String, JVal)>),
}

impl JVal {
    pub fn render(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            // Non-finite floats have no JSON representation; emit null so
            // a broken measurement fails schema validation loudly instead
            // of producing unparseable output.
            JVal::Num(x) if !x.is_finite() => out.push_str("null"),
            JVal::Num(x) => out.push_str(&format!("{x}")),
            JVal::Int(x) => out.push_str(&format!("{x}")),
            JVal::Str(s) => write_escaped(s, out),
            JVal::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            JVal::Arr(xs) => {
                out.push('[');
                for (i, x) in xs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            JVal::Obj(kvs) => {
                out.push('{');
                for (i, (k, v)) in kvs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Append `s` as a JSON string literal (quotes + escapes) to `out`.
fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

impl BenchResult {
    /// Schema-stable JSON object for the bench artifact.
    pub fn to_jval(&self) -> JVal {
        JVal::Obj(vec![
            ("name".into(), JVal::Str(self.name.clone())),
            ("iters".into(), JVal::Int(self.iters)),
            ("mean_ns".into(), JVal::Num(self.mean_ns)),
            ("p50_ns".into(), JVal::Num(self.p50_ns)),
            ("p99_ns".into(), JVal::Num(self.p99_ns)),
        ])
    }
}

/// Fixed-width text table, printed like the paper's tables.
pub struct Table {
    title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: impl Into<String>, header: &[&str]) -> Self {
        Table {
            title: title.into(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.header.len(), "column count mismatch");
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> =
            self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate() {
                widths[i] = widths[i].max(cell.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("\n## {}\n\n", self.title));
        let fmt_row = |cells: &[String]| -> String {
            let mut line = String::from("|");
            for (i, cell) in cells.iter().enumerate() {
                line.push_str(&format!(" {:<width$} |", cell, width = widths[i]));
            }
            line.push('\n');
            line
        };
        out.push_str(&fmt_row(&self.header));
        let mut sep = String::from("|");
        for w in &widths {
            sep.push_str(&format!("{}|", "-".repeat(w + 2)));
        }
        sep.push('\n');
        out.push_str(&sep);
        for row in &self.rows {
            out.push_str(&fmt_row(row));
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_fn_counts_iters() {
        let mut n = 0u64;
        let r = bench_fn("noop", 2, 10, || n += 1);
        assert_eq!(n, 12);
        assert_eq!(r.iters, 10);
        assert!(r.mean_ns >= 0.0);
    }

    #[test]
    fn time_once_returns_value() {
        let (v, dt) = time_once(|| 41 + 1);
        assert_eq!(v, 42);
        assert!(dt >= 0.0);
    }

    #[test]
    fn table_renders_markdown() {
        let mut t = Table::new("Test", &["a", "bb"]);
        t.row(vec!["1".into(), "2".into()]);
        let s = t.render();
        assert!(s.contains("## Test"));
        assert!(s.contains("| a "));
        assert!(s.contains("| 1 "));
        assert!(s.contains("|---"));
    }

    #[test]
    #[should_panic(expected = "column count mismatch")]
    fn table_rejects_bad_row() {
        let mut t = Table::new("x", &["a"]);
        t.row(vec!["1".into(), "2".into()]);
    }

    #[test]
    fn jval_round_trips_through_own_parser() {
        use crate::util::json;
        let v = JVal::Obj(vec![
            ("schema_version".into(), JVal::Int(1)),
            (
                "benchmarks".into(),
                JVal::Arr(vec![JVal::Obj(vec![
                    ("name".into(), JVal::Str("kv allocate+release".into())),
                    ("mean_ns".into(), JVal::Num(123.456)),
                ])]),
            ),
            ("quote \"esc\"\n".into(), JVal::Bool(true)),
            ("none".into(), JVal::Num(f64::NAN)),
        ]);
        let text = v.render();
        let parsed = json::parse(&text).expect("serializer must emit valid JSON");
        assert_eq!(
            parsed.path(&["schema_version"]).unwrap().as_f64(),
            Some(1.0)
        );
        let b = &parsed.get("benchmarks").unwrap().as_arr().unwrap()[0];
        assert_eq!(b.get("name").unwrap().as_str(), Some("kv allocate+release"));
        assert_eq!(b.get("mean_ns").unwrap().as_f64(), Some(123.456));
        assert_eq!(
            parsed.get("quote \"esc\"\n").unwrap().as_bool(),
            Some(true)
        );
        assert_eq!(parsed.get("none"), Some(&json::Value::Null));
    }

    #[test]
    fn bench_result_jval_has_stable_schema() {
        let r = BenchResult {
            name: "x".into(),
            iters: 5,
            mean_ns: 1.0,
            p50_ns: 2.0,
            p99_ns: 3.0,
        };
        let text = r.to_jval().render();
        let v = crate::util::json::parse(&text).unwrap();
        for key in ["name", "iters", "mean_ns", "p50_ns", "p99_ns"] {
            assert!(v.get(key).is_some(), "missing key {key} in {text}");
        }
    }
}
