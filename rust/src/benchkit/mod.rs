//! Minimal benchmark harness (criterion is unavailable offline).
//!
//! Two roles:
//! * wall-clock micro-benchmarks of the coordinator hot paths
//!   ([`bench_fn`]) with warmup, repetitions and basic statistics;
//! * experiment table formatting shared by the paper-reproduction
//!   benches ([`Table`]).

use std::time::Instant;

use crate::util::stats;

/// Result of a micro-benchmark.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p99_ns: f64,
}

impl BenchResult {
    pub fn summary(&self) -> String {
        format!(
            "{:<40} {:>10.0} ns/iter (p50 {:>10.0}, p99 {:>10.0}, n={})",
            self.name, self.mean_ns, self.p50_ns, self.p99_ns, self.iters
        )
    }
}

/// Time `f` with warmup; samples are per-call durations.
pub fn bench_fn<F: FnMut()>(name: &str, warmup: u32, iters: u32, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters as usize);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_nanos() as f64);
    }
    BenchResult {
        name: name.to_string(),
        iters: iters as u64,
        mean_ns: stats::mean(&samples),
        p50_ns: stats::percentile(&samples, 50.0),
        p99_ns: stats::percentile(&samples, 99.0),
    }
}

/// Measure a single long-running closure's wall time in seconds.
pub fn time_once<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed().as_secs_f64())
}

/// Fixed-width text table, printed like the paper's tables.
pub struct Table {
    title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: impl Into<String>, header: &[&str]) -> Self {
        Table {
            title: title.into(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.header.len(), "column count mismatch");
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> =
            self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate() {
                widths[i] = widths[i].max(cell.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("\n## {}\n\n", self.title));
        let fmt_row = |cells: &[String]| -> String {
            let mut line = String::from("|");
            for (i, cell) in cells.iter().enumerate() {
                line.push_str(&format!(" {:<width$} |", cell, width = widths[i]));
            }
            line.push('\n');
            line
        };
        out.push_str(&fmt_row(&self.header));
        let mut sep = String::from("|");
        for w in &widths {
            sep.push_str(&format!("{}|", "-".repeat(w + 2)));
        }
        sep.push('\n');
        out.push_str(&sep);
        for row in &self.rows {
            out.push_str(&fmt_row(row));
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_fn_counts_iters() {
        let mut n = 0u64;
        let r = bench_fn("noop", 2, 10, || n += 1);
        assert_eq!(n, 12);
        assert_eq!(r.iters, 10);
        assert!(r.mean_ns >= 0.0);
    }

    #[test]
    fn time_once_returns_value() {
        let (v, dt) = time_once(|| 41 + 1);
        assert_eq!(v, 42);
        assert!(dt >= 0.0);
    }

    #[test]
    fn table_renders_markdown() {
        let mut t = Table::new("Test", &["a", "bb"]);
        t.row(vec!["1".into(), "2".into()]);
        let s = t.render();
        assert!(s.contains("## Test"));
        assert!(s.contains("| a "));
        assert!(s.contains("| 1 "));
        assert!(s.contains("|---"));
    }

    #[test]
    #[should_panic(expected = "column count mismatch")]
    fn table_rejects_bad_row() {
        let mut t = Table::new("x", &["a"]);
        t.row(vec!["1".into(), "2".into()]);
    }
}
