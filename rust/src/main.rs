//! `cronus` — the launcher CLI.
//!
//! ```text
//! cronus serve            run the real tiny model end-to-end (PJRT)
//! cronus bench-table2     reproduce Table 2 (max throughput)
//! cronus bench-fig4       reproduce Fig. 4 (TTFT/TBT P99 under load)
//! cronus bench-table3     reproduce Table 3 (relative GPU utilization)
//! cronus bench-fig3       reproduce Fig. 3 (linear iteration-time fits)
//! cronus bench-cluster    sweep 1→N mixed pairs behind the cluster router
//! cronus repro            replay a scenario capsule under the invariant oracle
//! cronus plan-topology    search pair compositions under a budget, emit TOML
//! cronus calibrate        print the Balancer's fitted predictors
//! cronus trace            generate + summarize a workload trace
//! cronus info             show GPU specs / model geometries / defaults
//! ```
//!
//! Every subcommand takes `--n`, `--seed` and (where relevant) `--model`,
//! `--low-gpu`, `--config <file.toml>`; see `cronus <cmd> --help`.

use cronus::benchkit::Table;
use cronus::config::cli::Parser;
use cronus::config::{toml, DeploymentConfig};
use cronus::cronus::router::RoutePolicy;
use cronus::launcher::{self, ExperimentOpts};
use cronus::simgpu::model_desc;
use cronus::simgpu::spec;
use cronus::workload::azure::{generate, AzureTraceConfig};

fn common_parser(cmd: &'static str, about: &'static str) -> Parser {
    Parser::new(cmd, about)
        .opt("n", "requests per run", Some("1000"))
        .opt("seed", "workload seed", Some("42"))
        .opt("config", "TOML config file with deployment overrides", None)
        .opt("model", "model (llama3-8b | qwen2-7b)", Some("llama3-8b"))
        .opt("low-gpu", "low-end GPU (a10 | a30)", Some("a10"))
        .flag("help", "print usage")
}

fn deployment(args: &cronus::config::cli::Args) -> DeploymentConfig {
    let model = model_desc::by_name(args.get("model").unwrap()).unwrap_or_else(|| {
        eprintln!("unknown model {:?}", args.get("model"));
        std::process::exit(2);
    });
    let low = spec::by_name(args.get("low-gpu").unwrap()).unwrap_or_else(|| {
        eprintln!("unknown gpu {:?}", args.get("low-gpu"));
        std::process::exit(2);
    });
    let mut cfg = DeploymentConfig::paper(spec::A100, low, model);
    if let Some(path) = args.get("config") {
        let text = std::fs::read_to_string(path).unwrap_or_else(|e| {
            eprintln!("cannot read {path}: {e}");
            std::process::exit(2);
        });
        let doc = toml::parse(&text).unwrap_or_else(|e| {
            eprintln!("{path}: {e}");
            std::process::exit(2);
        });
        if let Err(e) = cfg.apply_toml(&doc) {
            eprintln!("{path}: {e}");
            std::process::exit(2);
        }
    }
    cfg
}

fn opts(args: &cronus::config::cli::Args) -> ExperimentOpts {
    ExperimentOpts {
        n_requests: args.get_usize("n").unwrap(),
        seed: args.get_u64("seed").unwrap(),
    }
}

/// Read and parse a TOML file, exiting with a diagnostic on failure.
fn load_toml(path: &str) -> toml::TomlDoc {
    let text = std::fs::read_to_string(path).unwrap_or_else(|e| {
        eprintln!("cannot read {path}: {e}");
        std::process::exit(2);
    });
    toml::parse(&text).unwrap_or_else(|e| {
        eprintln!("{path}: {e}");
        std::process::exit(2);
    })
}

/// Load a cluster topology from a TOML file's `[topology]` section,
/// starting from the standard 4-pair mixed fleet.
fn cluster_from_toml(path: &str) -> cronus::config::ClusterConfig {
    let doc = load_toml(path);
    let mut cluster =
        cronus::config::ClusterConfig::mixed(4, cronus::simgpu::model_desc::LLAMA3_8B);
    if let Err(e) = cluster.apply_toml(&doc) {
        eprintln!("{path}: {e}");
        std::process::exit(2);
    }
    cluster
}

fn main() {
    let mut raw: Vec<String> = std::env::args().skip(1).collect();
    let cmd = if raw.is_empty() { "help".to_string() } else { raw.remove(0) };
    match cmd.as_str() {
        "serve" => serve(&raw),
        "repro" => repro(&raw),
        "bench-table2" => with_parser(
            common_parser("cronus bench-table2", "reproduce Table 2"),
            &raw,
            |args| {
                let (table, _) = launcher::table2(&opts(args));
                table.print();
            },
        ),
        "bench-fig4" => with_parser(
            common_parser("cronus bench-fig4", "reproduce Fig. 4")
                .opt("rate-frac", "offered rate / slowest capacity", Some("0.7")),
            &raw,
            |args| {
                let panels =
                    launcher::fig4(&opts(args), args.get_f64("rate-frac").unwrap());
                let (ttft, tbt) = launcher::fig4_tables(&panels);
                ttft.print();
                tbt.print();
            },
        ),
        "bench-table3" => with_parser(
            common_parser("cronus bench-table3", "reproduce Table 3"),
            &raw,
            |args| launcher::table3(&opts(args)).print(),
        ),
        "bench-cluster" => with_parser(
            Parser::new(
                "cronus bench-cluster",
                "sweep 1→N mixed GPU pairs behind the cluster router",
            )
            .opt("n", "requests per run", Some("400"))
            .opt("seed", "workload seed", Some("42"))
            .opt("pairs", "max pairs to sweep (ignored with --config)", Some("4"))
            .opt(
                "policy",
                "route policy (round-robin | least-outstanding | slo-aware | \
                 kv-affinity)",
                Some("least-outstanding"),
            )
            .opt(
                "slo-ttft-ms",
                "TTFT SLO in ms for router admission control (0 = off)",
                Some("0"),
            )
            .opt("config", "TOML file with a [topology] section", None)
            .flag(
                "autoscale",
                "elastic fleet: grow/shrink the active pair set from router \
                 queue depth ([autoscale] keys in --config tune thresholds)",
            )
            .flag(
                "closed-loop",
                "serve multi-turn sessions closed-loop (think time between \
                 turns) and compare routing policies incl. kv-affinity",
            )
            .opt("sessions", "closed-loop sessions", Some("60"))
            .opt(
                "think-ms",
                "mean think time between turns in ms (closed-loop)",
                Some("2000"),
            )
            .flag(
                "classes",
                "multi-tenant QoS demo: premium/batch service classes, \
                 baseline (labels only) vs full contracts; writes \
                 BENCH_qos.json ($CRONUS_QOS_BENCH_JSON overrides the path)",
            )
            .opt("rate-rps", "offered request rate for --classes/--faults", Some("8"))
            .flag(
                "faults",
                "fault-injection demo: replay the same trace fault-free vs \
                 with deterministic pair failures ([faults] keys in --config \
                 tune the plan); writes BENCH_faults.json \
                 ($CRONUS_FAULTS_BENCH_JSON overrides the path)",
            )
            .opt(
                "fail",
                "comma-separated outages <pair>@<fail_s>[+<down_s>] appended \
                 to the --faults plan (e.g. 0@1+2,1@4)",
                None,
            )
            .flag(
                "migrate",
                "KV-migration demo: closed-loop sessions with forced drains, \
                 served with and without the inter-pair link; writes \
                 BENCH_migration.json ($CRONUS_MIGRATION_BENCH_JSON overrides \
                 the path)",
            )
            .opt(
                "link",
                "inter-pair link for --migrate, <gbps>G[@<lat>us][:<eff>] \
                 (a [cluster] link in --config takes precedence)",
                Some("100G"),
            )
            .flag(
                "check",
                "attach the online invariant oracle: replay the open-loop \
                 workload with every event checked (O(1) each) and exit 1 \
                 on any violation; honors --arrival, --fail, --autoscale \
                 and [faults]/[autoscale] keys in --config",
            )
            .opt(
                "arrival",
                "arrival process for --check (all-at-once | fixed | poisson \
                 | diurnal | bursty); rates come from --rate-rps and the \
                 process knobs below",
                Some("poisson"),
            )
            .opt("period-s", "diurnal period in seconds (--check)", Some("20"))
            .opt("peak-rps", "diurnal peak rate, req/s (--check)", Some("16"))
            .opt("trough-rps", "diurnal trough rate, req/s (--check)", Some("2"))
            .opt("burst-rps", "bursty in-burst rate, req/s (--check)", Some("40"))
            .opt(
                "burst-len-s",
                "bursty mean burst length in seconds (--check)",
                Some("1"),
            )
            .opt(
                "capture",
                "write the run's scenario capsule TOML to this file (--check)",
                None,
            )
            .flag("help", "print usage"),
            &raw,
            |args| {
                let policy_name = args.get("policy").unwrap();
                let policy = RoutePolicy::from_name(policy_name).unwrap_or_else(|| {
                    eprintln!("unknown route policy {policy_name:?}");
                    std::process::exit(2);
                });
                let slo_ms = args.get_f64("slo-ttft-ms").unwrap();
                let slo = (slo_ms > 0.0).then_some(slo_ms / 1e3);
                if args.has_flag("check") {
                    run_checked(args, policy, slo);
                    return;
                }
                if args.has_flag("autoscale") {
                    // Elastic-fleet mode: burst/trickle trace, scale
                    // events tabulated as they happen.
                    let cluster = match args.get("config") {
                        Some(path) => cluster_from_toml(path),
                        None => cronus::config::ClusterConfig::mixed(
                            args.get_usize("pairs").unwrap(),
                            cronus::simgpu::model_desc::LLAMA3_8B,
                        ),
                    };
                    let mut acfg = cronus::systems::AutoscaleConfig::default();
                    if let Some(path) = args.get("config") {
                        acfg.apply_toml(&load_toml(path));
                    }
                    let (table, out) =
                        launcher::autoscale_demo(&opts(args), &cluster, policy, &acfg);
                    table.print();
                    let r = &out.report;
                    println!(
                        "\n{} finished / {} rejected; scale +{}/-{}; \
                         TTFT p99 {:.3}s, TBT p99 {:.3}s",
                        r.n_finished,
                        r.n_rejected,
                        r.n_scale_ups,
                        r.n_scale_downs,
                        r.ttft_p99_s,
                        r.tbt_p99_s
                    );
                    return;
                }
                if args.has_flag("classes") {
                    // QoS mode: the same arrivals served with class
                    // labels only (baseline) and with the full contracts
                    // (weighted fair sharing + per-class SLOs).
                    let cluster = match args.get("config") {
                        Some(path) => cluster_from_toml(path),
                        None => cronus::config::ClusterConfig::mixed(
                            args.get_usize("pairs").unwrap(),
                            cronus::simgpu::model_desc::LLAMA3_8B,
                        ),
                    };
                    let rate = args.get_f64("rate-rps").unwrap();
                    let slo_s = if slo_ms > 0.0 { slo_ms / 1e3 } else { 1.0 };
                    // A `[classes]` table in --config replaces the
                    // built-in premium/batch contracts.
                    let mut registry = cronus::qos::ClassRegistry::new();
                    if let Some(path) = args.get("config") {
                        if let Err(e) = registry.apply_toml(&load_toml(path)) {
                            eprintln!("{path}: {e}");
                            std::process::exit(2);
                        }
                    }
                    let (table, points) = if registry.is_multi_class() {
                        launcher::qos_classes_demo_with(
                            &opts(args),
                            &cluster,
                            policy,
                            rate,
                            registry,
                        )
                    } else {
                        launcher::qos_classes_demo(
                            &opts(args),
                            &cluster,
                            policy,
                            rate,
                            slo_s,
                        )
                    };
                    table.print();
                    write_qos_artifact(args, &cluster, policy, rate, slo_s, &points);
                    return;
                }
                if args.has_flag("faults") {
                    // Fault-injection mode: the same open-loop arrivals
                    // served twice — undisturbed, then under a
                    // deterministic pair-failure plan — to measure what
                    // graceful degradation costs.
                    let cluster = match args.get("config") {
                        Some(path) => cluster_from_toml(path),
                        None => cronus::config::ClusterConfig::mixed(
                            args.get_usize("pairs").unwrap(),
                            cronus::simgpu::model_desc::LLAMA3_8B,
                        ),
                    };
                    let rate = args.get_f64("rate-rps").unwrap();
                    let mut fcfg = cronus::faults::FaultConfig::default();
                    if let Some(path) = args.get("config") {
                        if let Err(e) = fcfg.apply_toml(&load_toml(path)) {
                            eprintln!("{path}: {e}");
                            std::process::exit(2);
                        }
                    }
                    if let Some(specs) = args.get("fail") {
                        for spec in specs.split(',').filter(|s| !s.trim().is_empty()) {
                            match cronus::faults::parse_schedule_entry(spec.trim()) {
                                Ok(e) => fcfg.schedule.push(e),
                                Err(e) => {
                                    eprintln!("{e}");
                                    std::process::exit(2);
                                }
                            }
                        }
                    }
                    if fcfg.n_failures == 0 && fcfg.schedule.is_empty() {
                        // Out-of-the-box demo outage: pair 0 down at
                        // 1 s, repaired 2 s later.
                        fcfg.schedule.push(
                            cronus::faults::parse_schedule_entry("0@1+2").unwrap(),
                        );
                    }
                    let (table, points) = launcher::faults_demo(
                        &opts(args),
                        &cluster,
                        policy,
                        rate,
                        &fcfg,
                    )
                    .unwrap_or_else(|e| {
                        eprintln!("{e}");
                        std::process::exit(2);
                    });
                    table.print();
                    write_faults_artifact(args, &cluster, policy, rate, &fcfg, &points);
                    return;
                }
                if args.has_flag("migrate") {
                    // Migration mode: the same closed-loop session
                    // workload served twice — drains evicting warm KV
                    // (no link) vs handing it over the inter-pair link.
                    let cluster = match args.get("config") {
                        Some(path) => cluster_from_toml(path),
                        None => cronus::config::ClusterConfig::mixed(
                            args.get_usize("pairs").unwrap(),
                            cronus::simgpu::model_desc::LLAMA3_8B,
                        ),
                    };
                    let link = match cluster.link {
                        Some(l) => l,
                        None => {
                            let spec = args.get("link").unwrap();
                            cronus::simgpu::link::LinkSpec::parse(spec)
                                .unwrap_or_else(|e| {
                                    eprintln!("{e}");
                                    std::process::exit(2);
                                })
                        }
                    };
                    let (table, points) =
                        launcher::migration_demo(&opts(args), &cluster, link);
                    table.print();
                    if let Some(mig) =
                        points.iter().find(|p| p.label == "migrate")
                    {
                        let r = &mig.outcome.report;
                        println!(
                            "\nmigrate: {} prefixes shipped ({} tokens, \
                             {:.4}s on the wire)",
                            r.n_migrations, r.migrated_tokens, r.migration_time_s
                        );
                    }
                    write_migration_artifact(args, &cluster, link, &points);
                    return;
                }
                if args.has_flag("closed-loop") {
                    // Closed-loop mode: same session workload under every
                    // routing policy on a fixed cluster.
                    let cluster = match args.get("config") {
                        Some(path) => cluster_from_toml(path),
                        None => cronus::config::ClusterConfig::mixed(
                            args.get_usize("pairs").unwrap(),
                            cronus::simgpu::model_desc::LLAMA3_8B,
                        ),
                    };
                    let sessions = launcher::session_workload(
                        args.get_usize("sessions").unwrap(),
                        args.get_f64("think-ms").unwrap() / 1e3,
                        args.get_u64("seed").unwrap(),
                    );
                    let (table, points) =
                        launcher::session_affinity_sweep(&sessions, &cluster, slo);
                    table.print();
                    if let Some(aff) = points
                        .iter()
                        .find(|p| p.policy == RoutePolicy::KvAffinity)
                    {
                        let r = &aff.outcome.report;
                        println!(
                            "\nkv-affinity: {} hits ({:.0}% of follow-ups), \
                             {} prefill tokens saved",
                            r.n_kv_hits,
                            100.0 * r.kv_hit_rate,
                            r.prefill_tokens_saved
                        );
                    }
                    return;
                }
                let (table, points) = match args.get("config") {
                    Some(path) => {
                        let cluster = cluster_from_toml(path);
                        launcher::cluster_sweep_topology(
                            &opts(args),
                            policy,
                            &cluster,
                            slo,
                        )
                    }
                    None => launcher::cluster_sweep(
                        &opts(args),
                        policy,
                        args.get_usize("pairs").unwrap(),
                        slo,
                    ),
                };
                table.print();
                if let Some(last) = points.last() {
                    println!(
                        "\nscaling 1 → {} pairs: {:.2}x",
                        last.n_pairs, last.scaling
                    );
                }
            },
        ),
        "plan-topology" => with_parser(
            Parser::new(
                "cronus plan-topology",
                "search pair compositions under a cost/power budget and emit \
                 the winning [topology] TOML",
            )
            .opt("budget", "max fleet cost, USD/hour (0 = unconstrained)", Some("0"))
            .opt("power-budget", "max fleet power, watts (0 = unconstrained)", Some("0"))
            .opt("n", "requests in the scoring trace", Some("120"))
            .opt("seed", "scoring trace seed", Some("42"))
            .opt("model", "model (llama3-8b | qwen2-7b)", Some("llama3-8b"))
            .opt("beam", "beam width of the search", Some("3"))
            .opt("max-pairs", "largest fleet considered", Some("8"))
            .opt(
                "policy",
                "route policy candidates are scored under (round-robin | \
                 least-outstanding | slo-aware | kv-affinity)",
                Some("least-outstanding"),
            )
            .opt("out", "write the winning [topology] TOML to this file", None)
            .flag("help", "print usage"),
            &raw,
            |args| {
                let model =
                    model_desc::by_name(args.get("model").unwrap()).unwrap_or_else(|| {
                        eprintln!("unknown model {:?}", args.get("model"));
                        std::process::exit(2);
                    });
                let policy_name = args.get("policy").unwrap();
                let policy = RoutePolicy::from_name(policy_name).unwrap_or_else(|| {
                    eprintln!("unknown route policy {policy_name:?}");
                    std::process::exit(2);
                });
                let budget = args.get_f64("budget").unwrap();
                let power = args.get_f64("power-budget").unwrap();
                let cfg = cronus::planner::PlannerConfig {
                    budget_cost_per_hour: (budget > 0.0).then_some(budget),
                    budget_power_w: (power > 0.0).then_some(power),
                    beam_width: args.get_usize("beam").unwrap(),
                    max_pairs: args.get_usize("max-pairs").unwrap(),
                    n_requests: args.get_usize("n").unwrap(),
                    seed: args.get_u64("seed").unwrap(),
                    model,
                    policy,
                };
                let outcome = cronus::planner::plan(&cfg).unwrap_or_else(|e| {
                    eprintln!("{e}");
                    std::process::exit(2);
                });
                cronus::planner::report_table(&outcome).print();
                match &outcome.baseline {
                    Some(b) => println!(
                        "\npreset → planned at ${:.2}/hr: {:.2} → {:.2} req/s, \
                         TTFT p99 {:.3} → {:.3} s  ({} fleets evaluated)",
                        outcome.best.cost_per_hour,
                        b.throughput_rps,
                        outcome.best.throughput_rps,
                        b.ttft_p99_s,
                        outcome.best.ttft_p99_s,
                        outcome.n_evaluated
                    ),
                    None => println!(
                        "\nno mixed() preset prefix fits the budget \
                         ({} fleets evaluated)",
                        outcome.n_evaluated
                    ),
                }
                println!("\n{}", outcome.toml);
                if let Some(path) = args.get("out") {
                    std::fs::write(path, &outcome.toml).unwrap_or_else(|e| {
                        eprintln!("cannot write {path}: {e}");
                        std::process::exit(2);
                    });
                    eprintln!("wrote {path}");
                }
            },
        ),
        "bench-fig3" => with_parser(
            common_parser("cronus bench-fig3", "reproduce Fig. 3")
                .opt("noise", "profiling noise fraction", Some("0.008")),
            &raw,
            |args| {
                launcher::fig3(
                    args.get_f64("noise").unwrap(),
                    args.get_u64("seed").unwrap(),
                )
                .print()
            },
        ),
        "calibrate" => with_parser(
            common_parser("cronus calibrate", "fit the Balancer predictors"),
            &raw,
            |args| {
                let cfg = deployment(args);
                let ppi = cronus::simgpu::perfmodel::PerfModel::new(cfg.low_gpu, cfg.model);
                let cpi =
                    cronus::simgpu::perfmodel::PerfModel::new(cfg.high_gpu, cfg.model);
                let (p, c) = cronus::simgpu::fit::calibrate(
                    &ppi,
                    &cpi,
                    cfg.engine.max_batched_tokens,
                    cfg.calibration_noise,
                    cfg.calibration_seed,
                );
                println!(
                    "Eq.2 on {}: T = {:.3e}·L + {:.3e}  (R² {:.4}, MAPE {:.2}%)",
                    cfg.low_gpu.name, p.k_p, p.b_p, p.r2, p.mape * 100.0
                );
                println!(
                    "Eq.3 on {}: t = {:.3e}·Lp2 + {:.3e}·ΣLd + {:.3e}  (R² {:.4}, MAPE {:.2}%)",
                    cfg.high_gpu.name, c.k_ctxp, c.k_ctxd, c.b_c, c.r2, c.mape * 100.0
                );
            },
        ),
        "trace" => with_parser(
            common_parser("cronus trace", "generate + summarize a workload trace")
                .flag("short-long", "use the §6 short-input/long-output workload"),
            &raw,
            |args| {
                let wcfg = if args.has_flag("short-long") {
                    AzureTraceConfig::short_input_long_output()
                } else {
                    AzureTraceConfig::default()
                };
                let trace = generate(args.get_usize("n").unwrap(), &wcfg, args.get_u64("seed").unwrap());
                let s = cronus::workload::stats(&trace);
                println!("{s:?}");
                for r in trace.iter().take(10) {
                    println!("  req {:>4}: input {:>5}, output {:>5}", r.id, r.input_len, r.output_len);
                }
            },
        ),
        "info" => {
            let mut t = Table::new("GPU specs", &["name", "BF16 TFLOPS", "HBM GB/s", "mem GiB"]);
            for g in [spec::A100, spec::A30, spec::A10] {
                t.row(vec![
                    g.name.to_string(),
                    format!("{}", g.bf16_tflops),
                    format!("{}", g.hbm_gbps),
                    format!("{}", g.mem_gib),
                ]);
            }
            t.print();
            let mut t = Table::new(
                "Model geometries",
                &["name", "layers", "d_model", "kv heads", "params", "KV B/token"],
            );
            for m in [model_desc::LLAMA3_8B, model_desc::QWEN2_7B, model_desc::TINY] {
                t.row(vec![
                    m.name.to_string(),
                    m.n_layers.to_string(),
                    m.d_model.to_string(),
                    m.n_kv_heads.to_string(),
                    m.param_count().to_string(),
                    m.kv_bytes_per_token().to_string(),
                ]);
            }
            t.print();
        }
        "help" | "--help" | "-h" => print_help(),
        other => {
            eprintln!("unknown command '{other}'\n");
            print_help();
            std::process::exit(2);
        }
    }
}

/// `bench-cluster --check`: assemble a scenario capsule from the flags,
/// stream the open-loop run through the online invariant oracle (every
/// event checked as it is produced, O(1) each), and exit 1 on any
/// violation.  `--capture <file>` saves the capsule for `cronus repro`.
fn run_checked(args: &cronus::config::cli::Args, policy: RoutePolicy, slo: Option<f64>) {
    use cronus::checker::{InvariantChecker, Scenario, WorkloadSpec};
    use cronus::systems::driver::replay_trace_observed;
    use cronus::workload::arrival::ArrivalProcess;

    let cluster = match args.get("config") {
        Some(path) => cluster_from_toml(path),
        None => cronus::config::ClusterConfig::mixed(
            args.get_usize("pairs").unwrap(),
            cronus::simgpu::model_desc::LLAMA3_8B,
        ),
    };
    let seed = args.get_u64("seed").unwrap();
    let rate = args.get_f64("rate-rps").unwrap();
    let arrival_name = args.get("arrival").unwrap();
    let arrival = match arrival_name {
        "all-at-once" => Ok(ArrivalProcess::AllAtOnce),
        "fixed" => {
            ArrivalProcess::fixed(if rate > 0.0 { 1.0 / rate } else { 0.0 })
        }
        "poisson" => ArrivalProcess::poisson(rate, seed),
        "diurnal" => ArrivalProcess::diurnal(
            args.get_f64("period-s").unwrap(),
            args.get_f64("peak-rps").unwrap(),
            args.get_f64("trough-rps").unwrap(),
            seed,
        ),
        "bursty" => ArrivalProcess::bursty(
            rate,
            args.get_f64("burst-rps").unwrap(),
            args.get_f64("burst-len-s").unwrap(),
            seed,
        ),
        other => {
            eprintln!("unknown arrival process '{other}'");
            std::process::exit(2);
        }
    }
    .unwrap_or_else(|e| {
        eprintln!("{e}");
        std::process::exit(2);
    });
    // Fault plan: `[faults]` keys in --config plus any --fail entries.
    let mut fcfg = cronus::faults::FaultConfig::default();
    let mut have_faults = false;
    if let Some(path) = args.get("config") {
        let doc = load_toml(path);
        have_faults = !doc.section_keys("faults.").is_empty();
        if let Err(e) = fcfg.apply_toml(&doc) {
            eprintln!("{path}: {e}");
            std::process::exit(2);
        }
    }
    if let Some(specs) = args.get("fail") {
        for spec in specs.split(',').filter(|s| !s.trim().is_empty()) {
            match cronus::faults::parse_schedule_entry(spec.trim()) {
                Ok(e) => {
                    fcfg.schedule.push(e);
                    have_faults = true;
                }
                Err(e) => {
                    eprintln!("{e}");
                    std::process::exit(2);
                }
            }
        }
    }
    let autoscale = args.has_flag("autoscale").then(|| {
        let mut acfg = cronus::systems::AutoscaleConfig::default();
        if let Some(path) = args.get("config") {
            acfg.apply_toml(&load_toml(path));
        }
        acfg
    });
    let scenario = Scenario {
        name: "bench-cluster".to_string(),
        seed,
        policy,
        slo_ttft_s: slo,
        cluster,
        workload: WorkloadSpec::OpenLoop {
            n_requests: args.get_usize("n").unwrap(),
            trace_seed: seed,
            arrival,
        },
        autoscale,
        faults: have_faults.then_some(fcfg),
        classes: None,
        inject: None,
    };
    if let Some(path) = args.get("capture") {
        std::fs::write(path, scenario.to_toml()).unwrap_or_else(|e| {
            eprintln!("cannot write {path}: {e}");
            std::process::exit(2);
        });
        eprintln!("captured scenario capsule -> {path}");
    }
    let mut sys = scenario.build_system().unwrap_or_else(|e| {
        eprintln!("{e}");
        std::process::exit(2);
    });
    let trace = scenario.trace().unwrap_or_else(|e| {
        eprintln!("{e}");
        std::process::exit(2);
    });
    let mut checker = InvariantChecker::new()
        .with_faults(scenario.faults_active())
        .with_link(scenario.link_configured());
    checker.expect_trace(&trace);
    let (outcome, _stats) =
        replay_trace_observed(&mut sys, &trace, &mut |ev| checker.on_event(ev));
    checker.check_report(&outcome.report);
    let summary = checker.finish();
    let r = &outcome.report;
    println!(
        "{} requests on {} pairs ({}, {} arrivals): {} finished / {} rejected, \
         TTFT p99 {:.3}s",
        r.n_requests,
        scenario.cluster.n_pairs(),
        policy.name(),
        arrival_name,
        r.n_finished,
        r.n_rejected,
        r.ttft_p99_s
    );
    println!("{}", launcher::check_verdict(r, &summary));
    if !summary.ok() {
        std::process::exit(1);
    }
}

/// `cronus repro <case.toml> [--shrink] [--out <file>]`: replay a
/// scenario capsule under the invariant oracle.  Exits 0 when the run
/// is clean, 1 when the oracle flags violations; `--shrink` then also
/// minimizes the capsule (property: the first violation's kind still
/// fires) and writes the reduced `repro_*.toml`.
fn repro(raw: &[String]) {
    use cronus::checker::shrink::{run_scenario, shrink, ScenarioRun};
    use cronus::checker::{repro_dir, Scenario, WorkloadSpec};

    let usage = "usage: cronus repro <case.toml> [--shrink] [--out <file>]\n\n\
                 replay a scenario capsule under the online invariant oracle;\n\
                 --shrink minimizes a failing capsule to a minimal one that\n\
                 still trips the same violation (written to --out, or to\n\
                 $CRONUS_REPRO_DIR / the system temp dir)";
    let mut path: Option<String> = None;
    let mut do_shrink = false;
    let mut out: Option<String> = None;
    let mut i = 0;
    while i < raw.len() {
        match raw[i].as_str() {
            "--shrink" => do_shrink = true,
            "--out" => {
                i += 1;
                match raw.get(i) {
                    Some(p) => out = Some(p.clone()),
                    None => {
                        eprintln!("--out needs a file argument\n{usage}");
                        std::process::exit(2);
                    }
                }
            }
            "--help" | "-h" => {
                println!("{usage}");
                return;
            }
            other if !other.starts_with('-') && path.is_none() => {
                path = Some(other.to_string());
            }
            other => {
                eprintln!("unexpected argument '{other}'\n{usage}");
                std::process::exit(2);
            }
        }
        i += 1;
    }
    let path = path.unwrap_or_else(|| {
        eprintln!("{usage}");
        std::process::exit(2);
    });
    let text = std::fs::read_to_string(&path).unwrap_or_else(|e| {
        eprintln!("cannot read {path}: {e}");
        std::process::exit(2);
    });
    let scenario = Scenario::from_toml(&text).unwrap_or_else(|e| {
        eprintln!("{path}: {e}");
        std::process::exit(2);
    });
    let workload = match &scenario.workload {
        WorkloadSpec::OpenLoop { n_requests, .. } => {
            format!("{n_requests} open-loop requests")
        }
        WorkloadSpec::Explicit { requests } => {
            format!("{} explicit requests", requests.len())
        }
        WorkloadSpec::Sessions { sessions } => {
            format!("{} closed-loop sessions", sessions.n_sessions)
        }
    };
    println!(
        "replaying '{}': {} on {} pairs ({}{}{})",
        scenario.name,
        workload,
        scenario.cluster.n_pairs(),
        scenario.policy.name(),
        if scenario.faults_active() { ", faults" } else { "" },
        scenario
            .inject
            .map(|i| format!(", inject={}", i.name()))
            .unwrap_or_default(),
    );
    let run = run_scenario(&scenario).unwrap_or_else(|e| {
        eprintln!("{path}: {e}");
        std::process::exit(2);
    });
    println!("{}", launcher::check_verdict(&run.report, &run.summary));
    if run.summary.ok() {
        return;
    }
    if do_shrink {
        let kind = run.summary.violations[0].kind;
        let fails = move |r: &ScenarioRun| r.summary.has(kind);
        match shrink(&scenario, &fails) {
            Ok(outcome) => {
                let dest = out.unwrap_or_else(|| {
                    repro_dir()
                        .join(format!("repro_{}.toml", scenario.name))
                        .to_string_lossy()
                        .into_owned()
                });
                if let Some(dir) = std::path::Path::new(&dest).parent() {
                    let _ = std::fs::create_dir_all(dir);
                }
                std::fs::write(&dest, outcome.scenario.to_toml()).unwrap_or_else(
                    |e| {
                        eprintln!("cannot write {dest}: {e}");
                        std::process::exit(2);
                    },
                );
                let n_min = match &outcome.scenario.workload {
                    WorkloadSpec::OpenLoop { n_requests, .. } => *n_requests,
                    WorkloadSpec::Explicit { requests } => requests.len(),
                    WorkloadSpec::Sessions { sessions } => sessions.n_sessions,
                };
                println!(
                    "shrunk to {} request(s) on {} pair(s) in {} probes \
                     ({} rounds) -> {dest}",
                    n_min,
                    outcome.scenario.cluster.n_pairs(),
                    outcome.probes,
                    outcome.rounds
                );
            }
            Err(e) => eprintln!("shrink failed: {e}"),
        }
    }
    std::process::exit(1);
}

/// Emit the machine-readable QoS artifact for `bench-cluster --classes`
/// (schema v1; CI validates and archives it — record, don't gate, see
/// EXPERIMENTS.md §QoS isolation).
fn write_qos_artifact(
    args: &cronus::config::cli::Args,
    cluster: &cronus::config::ClusterConfig,
    policy: RoutePolicy,
    rate_rps: f64,
    slo_ttft_s: f64,
    points: &[launcher::QosDemoPoint],
) {
    use cronus::benchkit::JVal;
    let class_jval = |c: &cronus::metrics::ClassBreakdown| -> JVal {
        JVal::Obj(vec![
            ("name".into(), JVal::Str(c.name.clone())),
            ("requests".into(), JVal::Int(c.n_requests as u64)),
            ("finished".into(), JVal::Int(c.n_finished as u64)),
            ("shed".into(), JVal::Int(c.n_shed as u64)),
            ("throughput_rps".into(), JVal::Num(c.throughput_rps)),
            ("ttft_p99_s".into(), JVal::Num(c.ttft_p99_s)),
            ("tbt_p99_s".into(), JVal::Num(c.tbt_p99_s)),
        ])
    };
    let run_jval = |p: &launcher::QosDemoPoint| -> JVal {
        let r = &p.outcome.report;
        JVal::Obj(vec![
            ("run".into(), JVal::Str(p.label.into())),
            ("finished".into(), JVal::Int(r.n_finished as u64)),
            ("shed".into(), JVal::Int(r.n_rejected as u64)),
            ("ttft_p99_s".into(), JVal::Num(r.ttft_p99_s)),
            ("tbt_p99_s".into(), JVal::Num(r.tbt_p99_s)),
            (
                "classes".into(),
                JVal::Arr(r.classes.iter().map(class_jval).collect()),
            ),
        ])
    };
    let artifact = JVal::Obj(vec![
        ("schema_version".into(), JVal::Int(1)),
        ("generated_by".into(), JVal::Str("bench-cluster --classes".into())),
        (
            "workload".into(),
            JVal::Obj(vec![
                (
                    "n_requests".into(),
                    JVal::Int(args.get_usize("n").unwrap() as u64),
                ),
                ("seed".into(), JVal::Int(args.get_u64("seed").unwrap())),
                ("rate_rps".into(), JVal::Num(rate_rps)),
                ("premium_slo_ttft_s".into(), JVal::Num(slo_ttft_s)),
                ("policy".into(), JVal::Str(policy.name().into())),
                ("n_pairs".into(), JVal::Int(cluster.n_pairs() as u64)),
            ]),
        ),
        ("runs".into(), JVal::Arr(points.iter().map(run_jval).collect())),
    ]);
    let path = std::env::var("CRONUS_QOS_BENCH_JSON")
        .unwrap_or_else(|_| "BENCH_qos.json".to_string());
    std::fs::write(&path, artifact.render() + "\n").unwrap_or_else(|e| {
        eprintln!("cannot write {path}: {e}");
        std::process::exit(2);
    });
    println!("\nwrote {path}");
}

/// Emit the machine-readable fault-injection artifact for
/// `bench-cluster --faults` (schema v1; CI validates and archives it —
/// record, don't gate, see EXPERIMENTS.md §Faults).
fn write_faults_artifact(
    args: &cronus::config::cli::Args,
    cluster: &cronus::config::ClusterConfig,
    policy: RoutePolicy,
    rate_rps: f64,
    fcfg: &cronus::faults::FaultConfig,
    points: &[launcher::FaultsDemoPoint],
) {
    use cronus::benchkit::JVal;
    let run_jval = |p: &launcher::FaultsDemoPoint| -> JVal {
        let r = &p.outcome.report;
        let mean_rec = if r.recovery_latency_s.is_empty() {
            0.0
        } else {
            r.recovery_latency_s.iter().sum::<f64>() / r.recovery_latency_s.len() as f64
        };
        JVal::Obj(vec![
            ("run".into(), JVal::Str(p.label.into())),
            ("requests".into(), JVal::Int(r.n_requests as u64)),
            ("finished".into(), JVal::Int(r.n_finished as u64)),
            ("shed".into(), JVal::Int(r.n_rejected as u64)),
            ("pair_failures".into(), JVal::Int(r.n_pair_failures as u64)),
            ("retries".into(), JVal::Int(r.n_retries as u64)),
            ("recovered".into(), JVal::Int(r.n_recovered as u64)),
            ("recovery_latency_mean_s".into(), JVal::Num(mean_rec)),
            ("throughput_rps".into(), JVal::Num(r.throughput_rps)),
            ("ttft_p99_s".into(), JVal::Num(r.ttft_p99_s)),
            ("tbt_p99_s".into(), JVal::Num(r.tbt_p99_s)),
        ])
    };
    let n_planned = fcfg
        .build_plan(cluster.n_pairs())
        .map(|p| p.len())
        .unwrap_or(0);
    let artifact = JVal::Obj(vec![
        ("schema_version".into(), JVal::Int(1)),
        ("generated_by".into(), JVal::Str("bench-cluster --faults".into())),
        (
            "workload".into(),
            JVal::Obj(vec![
                (
                    "n_requests".into(),
                    JVal::Int(args.get_usize("n").unwrap() as u64),
                ),
                ("seed".into(), JVal::Int(args.get_u64("seed").unwrap())),
                ("rate_rps".into(), JVal::Num(rate_rps)),
                ("policy".into(), JVal::Str(policy.name().into())),
                ("n_pairs".into(), JVal::Int(cluster.n_pairs() as u64)),
                ("faults_seed".into(), JVal::Int(fcfg.seed)),
                ("n_planned_failures".into(), JVal::Int(n_planned as u64)),
            ]),
        ),
        ("runs".into(), JVal::Arr(points.iter().map(run_jval).collect())),
    ]);
    let path = std::env::var("CRONUS_FAULTS_BENCH_JSON")
        .unwrap_or_else(|_| "BENCH_faults.json".to_string());
    std::fs::write(&path, artifact.render() + "\n").unwrap_or_else(|e| {
        eprintln!("cannot write {path}: {e}");
        std::process::exit(2);
    });
    println!("\nwrote {path}");
}

/// Emit the machine-readable migration artifact for
/// `bench-cluster --migrate` (schema v1; CI validates and archives it —
/// record, don't gate, see EXPERIMENTS.md §Migration protocol).
fn write_migration_artifact(
    args: &cronus::config::cli::Args,
    cluster: &cronus::config::ClusterConfig,
    link: cronus::simgpu::link::LinkSpec,
    points: &[launcher::MigrationDemoPoint],
) {
    use cronus::benchkit::JVal;
    let run_jval = |p: &launcher::MigrationDemoPoint| -> JVal {
        let r = &p.outcome.report;
        JVal::Obj(vec![
            ("run".into(), JVal::Str(p.label.into())),
            ("finished_turns".into(), JVal::Int(p.stats.n_finished_turns as u64)),
            ("shed".into(), JVal::Int(r.n_rejected as u64)),
            (
                "prefill_tokens_executed".into(),
                JVal::Int(p.prefill_tokens_executed),
            ),
            ("prefill_tokens_saved".into(), JVal::Int(r.prefill_tokens_saved)),
            ("n_migrations".into(), JVal::Int(r.n_migrations as u64)),
            ("migrated_tokens".into(), JVal::Int(r.migrated_tokens)),
            ("migration_time_s".into(), JVal::Num(r.migration_time_s)),
            ("scale_downs".into(), JVal::Int(r.n_scale_downs as u64)),
            ("ttft_p99_s".into(), JVal::Num(r.ttft_p99_s)),
        ])
    };
    let artifact = JVal::Obj(vec![
        ("schema_version".into(), JVal::Int(1)),
        ("generated_by".into(), JVal::Str("bench-cluster --migrate".into())),
        (
            "workload".into(),
            JVal::Obj(vec![
                (
                    "n_sessions".into(),
                    JVal::Int(args.get_usize("n").unwrap() as u64),
                ),
                ("seed".into(), JVal::Int(args.get_u64("seed").unwrap())),
                ("link".into(), JVal::Str(link.spec())),
                ("n_pairs".into(), JVal::Int(cluster.n_pairs() as u64)),
            ]),
        ),
        ("runs".into(), JVal::Arr(points.iter().map(run_jval).collect())),
    ]);
    let path = std::env::var("CRONUS_MIGRATION_BENCH_JSON")
        .unwrap_or_else(|_| "BENCH_migration.json".to_string());
    std::fs::write(&path, artifact.render() + "\n").unwrap_or_else(|e| {
        eprintln!("cannot write {path}: {e}");
        std::process::exit(2);
    });
    println!("\nwrote {path}");
}

fn with_parser(
    parser: Parser,
    raw: &[String],
    f: impl FnOnce(&cronus::config::cli::Args),
) {
    let args = parser.parse(raw).unwrap_or_else(|e| {
        eprintln!("{e}\n{}", parser.usage());
        std::process::exit(2);
    });
    if args.has_flag("help") {
        println!("{}", parser.usage());
        return;
    }
    f(&args);
}

fn serve(raw: &[String]) {
    let parser = Parser::new("cronus serve", "serve real requests through the AOT model")
        .opt("n", "number of requests", Some("16"))
        .opt("seed", "workload seed", Some("7"))
        .flag("help", "print usage");
    with_parser(parser, raw, |args| {
        use cronus::server::{RealServer, ServeRequest};
        use cronus::util::rng::Rng;
        let dir = cronus::runtime::artifacts_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("no artifacts at {dir:?} — run `make artifacts` first");
            std::process::exit(2);
        }
        let n = args.get_usize("n").unwrap();
        let mut rng = Rng::new(args.get_u64("seed").unwrap());
        let server = RealServer::start(&dir).expect("server start");
        let t0 = std::time::Instant::now();
        for id in 0..n as u64 {
            let len = rng.range_usize(8, 200);
            let prompt: Vec<i32> =
                (0..len).map(|_| rng.range(1, 2047) as i32).collect();
            server.submit(ServeRequest { id, prompt, max_new_tokens: rng.range_usize(4, 32) });
        }
        let responses = server.shutdown().expect("serve");
        let wall = t0.elapsed().as_secs_f64();
        let tokens: usize = responses.iter().map(|r| r.tokens.len()).sum();
        println!(
            "{} requests, {tokens} tokens in {wall:.2}s ({:.1} tok/s)",
            responses.len(),
            tokens as f64 / wall
        );
    });
}

fn print_help() {
    println!(
        "cronus — partially disaggregated prefill for heterogeneous GPU clusters\n\n\
         subcommands:\n\
         \x20 serve          run the real tiny model end-to-end (PJRT CPU)\n\
         \x20 bench-table2   reproduce Table 2 (max throughput)\n\
         \x20 bench-fig4     reproduce Fig. 4 (TTFT/TBT P99 under load)\n\
         \x20 bench-table3   reproduce Table 3 (relative GPU utilization)\n\
         \x20 bench-fig3     reproduce Fig. 3 (linear iteration-time fits)\n\
         \x20 bench-cluster  sweep 1\u{2192}N mixed pairs behind the cluster router\n\
         \x20                (--autoscale: queue-driven elastic pair set;\n\
         \x20                 --classes: multi-tenant QoS service classes;\n\
         \x20                 --faults: deterministic pair-failure injection;\n\
         \x20                 --migrate: cross-pair KV migration over the link;\n\
         \x20                 --check: online invariant oracle on the stream)\n\
         \x20 repro          replay a scenario capsule under the invariant\n\
         \x20                oracle; --shrink minimizes failing capsules\n\
         \x20 plan-topology  search pair compositions under a budget, emit TOML\n\
         \x20 calibrate      print the Balancer's fitted predictors\n\
         \x20 trace          generate + summarize a workload trace\n\
         \x20 info           GPU specs / model geometries\n\n\
         run `cronus <cmd> --help` for options."
    );
}
