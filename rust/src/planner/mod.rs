//! Offline topology planning: search the pair-composition space for the
//! best `[topology]` under a cost or power budget.
//!
//! The planner answers the operator question the paper leaves open: *you
//! rent a heterogeneous GPU fleet — which (high, low) pairs should you
//! build, and how many?*  Candidate fleets are composed from "bricks" —
//! (high GPU, low GPU, serving system) triples where the high card
//! strictly dominates the low one in both achievable FLOPs and
//! bandwidth (the paper's premise for partial prefill offload) — and
//! scored by actually replaying a workload trace through a full
//! [`ClusterSystem`](crate::systems::cluster::ClusterSystem), not by a
//! closed-form proxy.  A beam search grows fleets one brick at a time
//! under the budget; the hand-written [`ClusterConfig::mixed`] preset
//! (trimmed to the largest prefix the budget allows) is seeded into the
//! beam, so the planner's answer is never worse than the preset at
//! equal budget.  Two cheap local post-passes then try
//! capacity-proportional rate shares and per-pair serving-system flips,
//! keeping each only if the replayed score improves.
//!
//! The winning fleet is emitted through [`ClusterConfig::to_toml`] and
//! round-tripped through the config parser before it is returned, so
//! the file `cronus plan-topology` writes is guaranteed to load.
//!
//! ```no_run
//! use cronus::planner::{plan, report_table, PlannerConfig};
//!
//! let cfg = PlannerConfig {
//!     budget_cost_per_hour: Some(12.0),
//!     ..Default::default()
//! };
//! let outcome = plan(&cfg).expect("some pair fits a $12/hr budget");
//! report_table(&outcome).print();
//! println!("{}", outcome.toml);
//! ```

use std::collections::BTreeSet;

use crate::benchkit::Table;
use crate::config::cluster::{DeploymentConfig, SystemKind};
use crate::config::toml;
use crate::config::topology::{ClusterConfig, PairConfig};
use crate::cronus::router::{RoutePolicy, Router};
use crate::launcher::cluster_max_throughput;
use crate::simgpu::model_desc::{self, ModelDesc};
use crate::simgpu::spec::{GpuSpec, ALL_GPUS};
use crate::workload::azure::{generate, AzureTraceConfig};
use crate::workload::Request;

/// Planner knobs.  Budgets are optional but in practice you set at
/// least one — an unconstrained search just buys the biggest fleet
/// `max_pairs` allows.
#[derive(Clone, Debug)]
pub struct PlannerConfig {
    /// Maximum fleet rental cost, USD/hour (both cards of every pair).
    pub budget_cost_per_hour: Option<f64>,
    /// Maximum fleet board power, watts.
    pub budget_power_w: Option<f64>,
    /// Beam width of the search (candidates kept per fleet size).
    pub beam_width: usize,
    /// Largest fleet considered.
    pub max_pairs: usize,
    /// Requests in the scoring trace (replayed per candidate).
    pub n_requests: usize,
    /// Seed of the scoring trace.
    pub seed: u64,
    /// Model every pair serves.
    pub model: ModelDesc,
    /// Routing policy candidates are scored under.
    pub policy: RoutePolicy,
}

impl Default for PlannerConfig {
    fn default() -> PlannerConfig {
        PlannerConfig {
            budget_cost_per_hour: None,
            budget_power_w: None,
            beam_width: 3,
            max_pairs: 8,
            n_requests: 120,
            seed: 42,
            model: model_desc::LLAMA3_8B,
            policy: RoutePolicy::LeastOutstandingTokens,
        }
    }
}

/// One scored fleet.
#[derive(Clone, Debug)]
pub struct Candidate {
    pub cluster: ClusterConfig,
    pub cost_per_hour: f64,
    pub power_w: f64,
    pub throughput_rps: f64,
    pub ttft_p99_s: f64,
    pub tbt_p99_s: f64,
}

/// Result of a planning run.
pub struct PlanOutcome {
    /// The winning fleet.
    pub best: Candidate,
    /// Top candidates, best first (at most ten).
    pub ranked: Vec<Candidate>,
    /// The hand-written `mixed()` preset trimmed to the budget, scored
    /// on the same trace — the before/after comparison point.  `None`
    /// when not even one preset pair fits.
    pub baseline: Option<Candidate>,
    /// Fleets actually replayed during the search.
    pub n_evaluated: usize,
    /// `best` as a `[topology]` TOML document (round-trip validated).
    pub toml: String,
}

/// The search's building blocks: every (high, low) combination where the
/// high card strictly dominates in both achievable FLOPs and bandwidth,
/// crossed with the two serving systems worth running on a pair.
fn bricks() -> Vec<(GpuSpec, GpuSpec, SystemKind)> {
    let mut out = Vec::new();
    for hi in ALL_GPUS {
        for lo in ALL_GPUS {
            if hi.flops() > lo.flops() && hi.bandwidth() > lo.bandwidth() {
                for system in [SystemKind::Cronus, SystemKind::DpChunked] {
                    out.push((hi, lo, system));
                }
            }
        }
    }
    out
}

fn brick_pair(
    hi: GpuSpec,
    lo: GpuSpec,
    system: SystemKind,
    model: ModelDesc,
) -> PairConfig {
    let mut p = PairConfig::cronus(DeploymentConfig::paper(hi, lo, model));
    p.system = system;
    p
}

fn fits(cluster: &ClusterConfig, cfg: &PlannerConfig) -> bool {
    cfg.budget_cost_per_hour.map_or(true, |b| cluster.cost_per_hour() <= b + 1e-9)
        && cfg.budget_power_w.map_or(true, |b| cluster.power_w() <= b + 1e-9)
}

/// Canonical multiset key of a fleet (pair order does not matter to the
/// router's policies, so permutations are the same candidate).
fn fleet_key(cluster: &ClusterConfig) -> String {
    let mut specs: Vec<String> = cluster.pairs.iter().map(|p| p.spec()).collect();
    specs.sort();
    specs.join("|")
}

fn evaluate(cluster: ClusterConfig, cfg: &PlannerConfig, trace: &[Request]) -> Candidate {
    let out = cluster_max_throughput(&cluster, cfg.policy, trace);
    Candidate {
        cost_per_hour: cluster.cost_per_hour(),
        power_w: cluster.power_w(),
        throughput_rps: out.report.throughput_rps,
        ttft_p99_s: out.report.ttft_p99_s,
        tbt_p99_s: out.report.tbt_p99_s,
        cluster,
    }
}

/// `a` strictly beats `b`: higher throughput, or equal throughput with
/// lower TTFT P99.
pub fn better(a: &Candidate, b: &Candidate) -> bool {
    if (a.throughput_rps - b.throughput_rps).abs() > 1e-9 {
        return a.throughput_rps > b.throughput_rps;
    }
    a.ttft_p99_s < b.ttft_p99_s
}

fn rank(v: &mut [Candidate]) {
    v.sort_by(|a, b| {
        b.throughput_rps
            .partial_cmp(&a.throughput_rps)
            .expect("throughput is never NaN")
            .then(a.ttft_p99_s.partial_cmp(&b.ttft_p99_s).expect("ttft is never NaN"))
    });
}

/// The hand-written preset trimmed to the largest prefix the budget
/// allows.
fn mixed_baseline(cfg: &PlannerConfig) -> Option<ClusterConfig> {
    let full = ClusterConfig::mixed(cfg.max_pairs, cfg.model);
    (1..=cfg.max_pairs)
        .rev()
        .map(|n| ClusterConfig::new(full.pairs[..n].to_vec()))
        .find(|c| fits(c, cfg))
}

/// Run the search.  Errors only when no single brick fits the budget or
/// when the emitted TOML fails its own round-trip validation (a bug,
/// not an input condition).
pub fn plan(cfg: &PlannerConfig) -> Result<PlanOutcome, String> {
    assert!(cfg.beam_width > 0 && cfg.max_pairs > 0, "degenerate planner config");
    let trace = generate(cfg.n_requests, &AzureTraceConfig::default(), cfg.seed);
    let bricks = bricks();
    let mut seen: BTreeSet<String> = BTreeSet::new();
    let mut n_evaluated = 0usize;
    let mut ranked: Vec<Candidate> = Vec::new();

    // Level 1: every single brick that fits, plus the mixed() preset
    // prefix — seeding the preset makes the final answer no worse than
    // the hand-written fleet at equal budget, by construction.
    let mut beam: Vec<Candidate> = Vec::new();
    for &(hi, lo, system) in &bricks {
        let c = ClusterConfig::new(vec![brick_pair(hi, lo, system, cfg.model)]);
        if !fits(&c, cfg) || !seen.insert(fleet_key(&c)) {
            continue;
        }
        n_evaluated += 1;
        beam.push(evaluate(c, cfg, &trace));
    }
    let baseline = mixed_baseline(cfg).map(|c| {
        n_evaluated += 1;
        evaluate(c, cfg, &trace)
    });
    if let Some(b) = &baseline {
        if seen.insert(fleet_key(&b.cluster)) {
            beam.push(b.clone());
        }
    }
    if beam.is_empty() {
        return Err("no (high, low) pair fits the budget".into());
    }
    rank(&mut beam);
    beam.truncate(cfg.beam_width);
    ranked.extend(beam.iter().cloned());

    // Grow the beam one brick at a time while the budget allows.
    loop {
        let mut next: Vec<Candidate> = Vec::new();
        for cand in &beam {
            if cand.cluster.n_pairs() >= cfg.max_pairs {
                continue;
            }
            for &(hi, lo, system) in &bricks {
                let mut pairs = cand.cluster.pairs.clone();
                pairs.push(brick_pair(hi, lo, system, cfg.model));
                let c = ClusterConfig::new(pairs);
                if !fits(&c, cfg) || !seen.insert(fleet_key(&c)) {
                    continue;
                }
                n_evaluated += 1;
                next.push(evaluate(c, cfg, &trace));
            }
        }
        if next.is_empty() {
            break;
        }
        rank(&mut next);
        next.truncate(cfg.beam_width);
        ranked.extend(next.iter().cloned());
        beam = next;
    }

    rank(&mut ranked);
    ranked.truncate(10);
    let mut best = ranked[0].clone();

    // Post-pass 1: capacity-proportional rate shares (normalized so the
    // slowest pair gets 1.0, rounded to two decimals for a readable
    // TOML).  Only matters under share-weighted routing, and is kept
    // only if the replayed score actually improves.
    let rates = Router::new(cfg.policy, &best.cluster).drain_rates_tps();
    let slowest = rates.iter().cloned().fold(f64::INFINITY, f64::min);
    if slowest > 0.0 && best.cluster.n_pairs() > 1 {
        let mut tuned = best.cluster.clone();
        for (p, r) in tuned.pairs.iter_mut().zip(&rates) {
            p.rate_share = (r / slowest * 100.0).round() / 100.0;
        }
        n_evaluated += 1;
        let cand = evaluate(tuned, cfg, &trace);
        if better(&cand, &best) {
            best = cand.clone();
            ranked.insert(0, cand);
            ranked.truncate(10);
        }
    }

    // Post-pass 2: flip each pair's serving system between Cronus and
    // DP+Chunked, keeping a flip only when it wins on the replay.
    for i in 0..best.cluster.n_pairs() {
        let flipped = match best.cluster.pairs[i].system {
            SystemKind::Cronus => SystemKind::DpChunked,
            _ => SystemKind::Cronus,
        };
        let mut tuned = best.cluster.clone();
        tuned.pairs[i].system = flipped;
        n_evaluated += 1;
        let cand = evaluate(tuned, cfg, &trace);
        if better(&cand, &best) {
            best = cand.clone();
            ranked.insert(0, cand);
            ranked.truncate(10);
        }
    }

    let toml_text = best.cluster.to_toml();
    validate_roundtrip(&toml_text, &best.cluster)?;
    Ok(PlanOutcome { best, ranked, baseline, n_evaluated, toml: toml_text })
}

/// Parse the emitted TOML back through the config layer and check it
/// reproduces the fleet exactly — the guarantee behind handing the file
/// straight to `cronus bench-cluster --config`.
fn validate_roundtrip(text: &str, want: &ClusterConfig) -> Result<(), String> {
    let doc =
        toml::parse(text).map_err(|e| format!("emitted TOML failed to parse: {e:?}"))?;
    let mut got = ClusterConfig::default();
    got.apply_toml(&doc)?;
    if got.n_pairs() != want.n_pairs() {
        return Err("emitted TOML changed the pair count".into());
    }
    for (a, b) in got.pairs.iter().zip(&want.pairs) {
        if a.deployment.high_gpu != b.deployment.high_gpu
            || a.deployment.low_gpu != b.deployment.low_gpu
            || a.deployment.model != b.deployment.model
            || a.system != b.system
            || a.rate_share != b.rate_share
        {
            return Err(format!("emitted TOML changed pair '{}'", b.spec()));
        }
    }
    Ok(())
}

/// Render the ranked candidates (and the preset baseline, if any) as a
/// report table.
pub fn report_table(outcome: &PlanOutcome) -> Table {
    let mut t = Table::new(
        "topology plan (ranked by replayed throughput)",
        &["fleet", "pairs", "$/hr", "watts", "req/s", "TTFT p99 (s)", "TBT p99 (s)"],
    );
    let mut push = |label: &str, c: &Candidate| {
        let specs: Vec<String> = c.cluster.pairs.iter().map(|p| p.spec()).collect();
        t.row(vec![
            format!("{label}{}", specs.join(", ")),
            c.cluster.n_pairs().to_string(),
            format!("{:.2}", c.cost_per_hour),
            format!("{:.0}", c.power_w),
            format!("{:.2}", c.throughput_rps),
            format!("{:.3}", c.ttft_p99_s),
            format!("{:.3}", c.tbt_p99_s),
        ]);
    };
    for c in &outcome.ranked {
        push("", c);
    }
    if let Some(b) = &outcome.baseline {
        push("[preset] ", b);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bricks_respect_the_domination_premise() {
        let b = bricks();
        // 8 dominating GPU combos x 2 systems (see simgpu::spec ladder).
        assert_eq!(b.len(), 16);
        for (hi, lo, _) in &b {
            assert!(hi.flops() > lo.flops(), "{}+{}", hi.name, lo.name);
            assert!(hi.bandwidth() > lo.bandwidth(), "{}+{}", hi.name, lo.name);
        }
        // The V100 has more bandwidth but fewer FLOPs than the A30:
        // neither dominates the other, so neither pairing is a brick.
        assert!(!b.iter().any(|(h, l, _)| h.name == "V100-32G" && l.name == "A30"));
        assert!(!b.iter().any(|(h, l, _)| h.name == "A30" && l.name == "V100-32G"));
    }

    #[test]
    fn fleet_key_ignores_pair_order() {
        let model = model_desc::LLAMA3_8B;
        let a = ClusterConfig::new(vec![
            brick_pair(ALL_GPUS[0], ALL_GPUS[3], SystemKind::Cronus, model),
            brick_pair(ALL_GPUS[0], ALL_GPUS[4], SystemKind::DpChunked, model),
        ]);
        let b = ClusterConfig::new(vec![a.pairs[1].clone(), a.pairs[0].clone()]);
        assert_eq!(fleet_key(&a), fleet_key(&b));
    }

    #[test]
    fn tight_budget_plans_a_single_cheap_pair() {
        // At $1/hr only A10+T4 fits (0.60 + 0.35); the preset's A100
        // pairs never do, so there is no baseline.
        let cfg = PlannerConfig {
            budget_cost_per_hour: Some(1.0),
            n_requests: 10,
            beam_width: 2,
            max_pairs: 3,
            ..Default::default()
        };
        let out = plan(&cfg).expect("a10+t4 fits");
        assert!(out.baseline.is_none());
        assert_eq!(out.best.cluster.n_pairs(), 1);
        assert!(out.best.cost_per_hour <= 1.0);
        assert_eq!(out.best.cluster.pairs[0].deployment.high_gpu.name, "A10");
        assert!(out.best.throughput_rps > 0.0);
        assert!(out.toml.contains("[topology]"));
    }

    #[test]
    fn impossible_budget_is_an_error() {
        let cfg = PlannerConfig {
            budget_cost_per_hour: Some(0.1),
            n_requests: 10,
            ..Default::default()
        };
        assert!(plan(&cfg).is_err());
    }
}
