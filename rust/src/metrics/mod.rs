//! Metrics substrate: per-request latency bookkeeping and the three
//! quantities the paper evaluates — throughput, TTFT P99, TBT P99.
//!
//! TTFT (time-to-first-token) is first-token time minus arrival; for the
//! disaggregated/partial-prefill systems it *includes* the KV-cache
//! transfer, matching the paper's measurement rule.  TBT
//! (time-between-tokens) is every inter-token gap in the decode phase;
//! P99 is taken over all gaps of all requests.

use crate::simclock::SimTime;
use crate::util::stats::{mean, percentile_of_sorted};
use crate::util::fxhash::FxHashMap;

pub type ReqId = u64;

#[derive(Clone, Debug)]
struct RequestRecord {
    arrival: SimTime,
    first_token: Option<SimTime>,
    last_token: Option<SimTime>,
    tbt_gaps_s: Vec<f64>,
    finished: Option<SimTime>,
    output_tokens: usize,
    /// Terminally shed (vs merely unfinished) — a fault abort must not
    /// forget shed records, only genuinely in-flight ones.
    shed: bool,
}

/// Collects per-request events during a run; produces a [`Report`].
#[derive(Default)]
pub struct Collector {
    records: FxHashMap<ReqId, RequestRecord>,
    /// Requests shed (rejected / dropped) instead of served.
    n_shed: usize,
}

impl Collector {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn on_arrival(&mut self, req: ReqId, t: SimTime) {
        let prev = self.records.insert(
            req,
            RequestRecord {
                arrival: t,
                first_token: None,
                last_token: None,
                tbt_gaps_s: Vec::new(),
                finished: None,
                output_tokens: 0,
                shed: false,
            },
        );
        debug_assert!(prev.is_none(), "request {req} arrived twice");
    }

    /// A generated token at time `t`.  The first call records TTFT; later
    /// calls record TBT gaps.
    pub fn on_token(&mut self, req: ReqId, t: SimTime) {
        let rec = self.records.get_mut(&req).expect("token for unknown request");
        match rec.last_token {
            None => rec.first_token = Some(t),
            Some(prev) => {
                debug_assert!(t >= prev, "token time went backwards");
                rec.tbt_gaps_s.push(t.saturating_sub(prev).as_secs_f64());
            }
        }
        rec.last_token = Some(t);
        rec.output_tokens += 1;
    }

    pub fn on_finish(&mut self, req: ReqId, t: SimTime) {
        let rec = self.records.get_mut(&req).expect("finish for unknown request");
        debug_assert!(rec.finished.is_none(), "request {req} finished twice");
        rec.finished = Some(t);
    }

    /// The request was shed (rejected at admission or dropped); it stays
    /// in `n_requests` but is surfaced via [`Report::n_rejected`].
    pub fn on_shed(&mut self, req: ReqId) {
        self.n_shed += 1;
        if let Some(rec) = self.records.get_mut(&req) {
            rec.shed = true;
        }
    }

    /// Fault abort: erase `req`'s record entirely, as if it never
    /// arrived — it contributes to no count and no latency sample.
    /// No-op for unknown ids.
    pub fn forget(&mut self, req: ReqId) {
        self.records.remove(&req);
    }

    /// Fault abort for systems that track in-flight work only through
    /// their records: [`forget`](Collector::forget) every request that
    /// reached no terminal state (neither finished nor shed).  Returns
    /// the forgotten ids, ascending.
    pub fn drop_unfinished(&mut self) -> Vec<ReqId> {
        let mut ids: Vec<ReqId> = self
            .records
            .iter()
            .filter(|(_, r)| r.finished.is_none() && !r.shed)
            .map(|(id, _)| *id)
            .collect();
        ids.sort_unstable();
        for id in &ids {
            self.records.remove(id);
        }
        ids
    }

    pub fn n_shed(&self) -> usize {
        self.n_shed
    }

    pub fn n_arrived(&self) -> usize {
        self.records.len()
    }

    pub fn n_finished(&self) -> usize {
        self.records.values().filter(|r| r.finished.is_some()).count()
    }

    /// Build the final report.  `makespan` is the completion time of the
    /// last request (simulated), which defines throughput.
    pub fn report(&self, label: impl Into<String>) -> Report {
        let mut ttft = Vec::with_capacity(self.records.len());
        let mut tbt =
            Vec::with_capacity(self.records.values().map(|r| r.tbt_gaps_s.len()).sum());
        let mut e2e = Vec::with_capacity(self.records.len());
        let mut makespan = SimTime::ZERO;
        let mut finished = 0usize;
        let mut total_output_tokens = 0usize;
        for rec in self.records.values() {
            if let Some(ft) = rec.first_token {
                ttft.push(ft.saturating_sub(rec.arrival).as_secs_f64());
            }
            tbt.extend_from_slice(&rec.tbt_gaps_s);
            if let Some(done) = rec.finished {
                finished += 1;
                makespan = makespan.max(done);
                e2e.push(done.saturating_sub(rec.arrival).as_secs_f64());
                total_output_tokens += rec.output_tokens;
            }
        }
        let mut report = Report::from_samples(
            label,
            self.records.len(),
            finished,
            total_output_tokens,
            makespan.as_secs_f64(),
            ttft,
            tbt,
            e2e,
        );
        report.n_rejected = self.n_shed;
        report
    }
}

/// Per-service-class slice of a cluster run (QoS observability): the
/// same headline numbers as [`Report`], restricted to one class's
/// requests.  Raw samples are retained so merging cluster reports keeps
/// per-class percentiles exact, like the top-level ones.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ClassBreakdown {
    pub name: String,
    pub n_requests: usize,
    pub n_finished: usize,
    /// Requests of this class shed (model mismatch, SLO rejection, pair
    /// rejection) instead of served.
    pub n_shed: usize,
    pub throughput_rps: f64,
    pub ttft_p99_s: f64,
    pub tbt_p99_s: f64,
    /// Requests of this class re-submitted after a pair failure aborted
    /// them mid-flight (fault injection; 0 without a fault plan).
    pub n_retries: usize,
    /// Raw TTFT samples of this class, sorted ascending.
    pub ttft_samples: Vec<f64>,
    /// Raw inter-token gaps of this class, sorted ascending.
    pub tbt_samples: Vec<f64>,
}

impl ClassBreakdown {
    /// Assemble a class slice from raw samples; `makespan_s` is the
    /// *run's* makespan (per-class throughput shares the run clock).
    pub fn from_samples(
        name: impl Into<String>,
        n_requests: usize,
        n_finished: usize,
        n_shed: usize,
        makespan_s: f64,
        mut ttft: Vec<f64>,
        mut tbt: Vec<f64>,
    ) -> ClassBreakdown {
        ttft.retain(|v| v.is_finite());
        tbt.retain(|v| v.is_finite());
        ttft.sort_unstable_by(f64::total_cmp);
        tbt.sort_unstable_by(f64::total_cmp);
        ClassBreakdown {
            name: name.into(),
            n_requests,
            n_finished,
            n_shed,
            throughput_rps: if makespan_s > 0.0 {
                n_finished as f64 / makespan_s
            } else {
                0.0
            },
            ttft_p99_s: percentile_of_sorted(&ttft, 99.0),
            tbt_p99_s: percentile_of_sorted(&tbt, 99.0),
            n_retries: 0,
            ttft_samples: ttft,
            tbt_samples: tbt,
        }
    }
}

/// Aggregate results of one run (one cell of a paper table / one point of
/// a paper figure).
///
/// Besides the summary statistics, a report keeps its raw per-request
/// latency samples so reports from independent instances (the pairs of a
/// cluster) can be [merged](Report::merge) into exact cluster-wide
/// percentiles — percentiles of percentiles would be wrong.
#[derive(Clone, Debug)]
pub struct Report {
    pub label: String,
    pub n_requests: usize,
    pub n_finished: usize,
    /// Requests shed instead of served (admission rejections, oversized
    /// prompts, SLO sheds).  Counted inside `n_requests`.
    pub n_rejected: usize,
    /// Output tokens of finished requests (defines token throughput).
    pub n_output_tokens: usize,
    pub makespan_s: f64,
    pub throughput_rps: f64,
    pub token_throughput_tps: f64,
    pub ttft_mean_s: f64,
    pub ttft_p50_s: f64,
    pub ttft_p99_s: f64,
    pub tbt_mean_s: f64,
    pub tbt_p50_s: f64,
    pub tbt_p99_s: f64,
    pub e2e_p50_s: f64,
    pub e2e_p99_s: f64,
    /// Follow-up turns routed to the pair already holding their session's
    /// prefix KV (cluster-level; 0 outside KV-affinity routing).
    pub n_kv_hits: usize,
    /// Prefill tokens those hits skipped (neither recomputed nor
    /// transferred).
    pub prefill_tokens_saved: u64,
    /// Follow-up turns routed (turns with a non-empty session prefix) —
    /// the denominator of `kv_hit_rate`, carried so merging reports
    /// keeps the rate consistent.
    pub n_prefix_routed: usize,
    /// `n_kv_hits` / `n_prefix_routed`; 0.0 when the workload has no
    /// follow-up turns.
    pub kv_hit_rate: f64,
    /// Standby pairs activated by the fleet controller during the run
    /// (cluster-level; 0 without `--autoscale`).
    pub n_scale_ups: usize,
    /// Pairs drained and retired to standby by the fleet controller.
    pub n_scale_downs: usize,
    /// Pair outages injected by a fault plan (cluster-level; 0 without
    /// one).
    pub n_pair_failures: usize,
    /// Failure-retry submissions: requests re-offered to admission after
    /// a pair failure aborted them mid-flight.
    pub n_retries: usize,
    /// Outages that repaired and rejoined during the run.
    pub n_recovered: usize,
    /// Outage durations (seconds) of the repaired failures, sorted
    /// ascending (kept raw so merged reports keep exact percentiles).
    pub recovery_latency_s: Vec<f64>,
    /// Warm prefixes shipped to another pair over the inter-pair link
    /// instead of recomputed (cluster-level; 0 without a configured
    /// link).
    pub n_migrations: usize,
    /// Prefix tokens those migrations carried across the link.
    pub migrated_tokens: u64,
    /// Total time the migrated KV spent on the wire, seconds.
    pub migration_time_s: f64,
    /// Per-service-class breakdown (cluster runs with a QoS class
    /// registry attached; empty otherwise).  Ordered by class id.
    pub classes: Vec<ClassBreakdown>,
    /// Raw TTFT samples, one per request that produced a first token.
    /// Sorted ascending ([`Report::from_samples`] sorts once and derives
    /// every percentile from the sorted vector).
    pub ttft_samples: Vec<f64>,
    /// Raw inter-token gaps across all requests (sorted ascending).
    pub tbt_samples: Vec<f64>,
    /// Raw end-to-end latencies of finished requests (sorted ascending).
    pub e2e_samples: Vec<f64>,
}

impl Report {
    /// Assemble a report from raw samples (shared by [`Collector::report`]
    /// and [`Report::merge`]).
    ///
    /// Each sample vector is sorted exactly once and every percentile is
    /// read off the sorted data (`percentile` used to clone + sort the
    /// vector 2–3 times per statistic — see EXPERIMENTS.md §Perf).  The
    /// sorted vectors are retained as the raw samples, which also makes
    /// the mean independent of collection order.
    pub fn from_samples(
        label: impl Into<String>,
        n_requests: usize,
        n_finished: usize,
        n_output_tokens: usize,
        makespan_s: f64,
        mut ttft: Vec<f64>,
        mut tbt: Vec<f64>,
        mut e2e: Vec<f64>,
    ) -> Report {
        // Reject non-finite samples up front: a NaN would previously
        // panic the `partial_cmp(..).unwrap()` sort, and `total_cmp`
        // alone would let it pollute the percentiles.
        ttft.retain(|v| v.is_finite());
        tbt.retain(|v| v.is_finite());
        e2e.retain(|v| v.is_finite());
        ttft.sort_unstable_by(f64::total_cmp);
        tbt.sort_unstable_by(f64::total_cmp);
        e2e.sort_unstable_by(f64::total_cmp);
        Report {
            label: label.into(),
            n_requests,
            n_finished,
            n_rejected: 0,
            n_output_tokens,
            makespan_s,
            throughput_rps: if makespan_s > 0.0 {
                n_finished as f64 / makespan_s
            } else {
                0.0
            },
            token_throughput_tps: if makespan_s > 0.0 {
                n_output_tokens as f64 / makespan_s
            } else {
                0.0
            },
            ttft_mean_s: mean(&ttft),
            ttft_p50_s: percentile_of_sorted(&ttft, 50.0),
            ttft_p99_s: percentile_of_sorted(&ttft, 99.0),
            tbt_mean_s: mean(&tbt),
            tbt_p50_s: percentile_of_sorted(&tbt, 50.0),
            tbt_p99_s: percentile_of_sorted(&tbt, 99.0),
            e2e_p50_s: percentile_of_sorted(&e2e, 50.0),
            e2e_p99_s: percentile_of_sorted(&e2e, 99.0),
            n_kv_hits: 0,
            prefill_tokens_saved: 0,
            n_prefix_routed: 0,
            kv_hit_rate: 0.0,
            n_scale_ups: 0,
            n_scale_downs: 0,
            n_pair_failures: 0,
            n_retries: 0,
            n_recovered: 0,
            recovery_latency_s: Vec::new(),
            n_migrations: 0,
            migrated_tokens: 0,
            migration_time_s: 0.0,
            classes: Vec::new(),
            ttft_samples: ttft,
            tbt_samples: tbt,
            e2e_samples: e2e,
        }
    }

    /// Merge per-instance reports into one cluster-wide report: counts
    /// and tokens add, the makespan is the latest finish (all instances
    /// share the experiment's t = 0), and percentiles are recomputed over
    /// the union of the raw samples.
    pub fn merge(label: impl Into<String>, parts: &[Report]) -> Report {
        let mut ttft =
            Vec::with_capacity(parts.iter().map(|p| p.ttft_samples.len()).sum());
        let mut tbt =
            Vec::with_capacity(parts.iter().map(|p| p.tbt_samples.len()).sum());
        let mut e2e =
            Vec::with_capacity(parts.iter().map(|p| p.e2e_samples.len()).sum());
        let mut n_requests = 0usize;
        let mut n_finished = 0usize;
        let mut n_rejected = 0usize;
        let mut n_output_tokens = 0usize;
        let mut n_kv_hits = 0usize;
        let mut prefill_tokens_saved = 0u64;
        let mut n_prefix_routed = 0usize;
        let mut n_scale_ups = 0usize;
        let mut n_scale_downs = 0usize;
        let mut n_pair_failures = 0usize;
        let mut n_retries = 0usize;
        let mut n_recovered = 0usize;
        let mut recovery_latency_s = Vec::new();
        let mut n_migrations = 0usize;
        let mut migrated_tokens = 0u64;
        let mut migration_time_s = 0.0f64;
        let mut makespan_s = 0.0f64;
        for p in parts {
            n_requests += p.n_requests;
            n_finished += p.n_finished;
            n_rejected += p.n_rejected;
            n_output_tokens += p.n_output_tokens;
            n_kv_hits += p.n_kv_hits;
            prefill_tokens_saved += p.prefill_tokens_saved;
            n_prefix_routed += p.n_prefix_routed;
            n_scale_ups += p.n_scale_ups;
            n_scale_downs += p.n_scale_downs;
            n_pair_failures += p.n_pair_failures;
            n_retries += p.n_retries;
            n_recovered += p.n_recovered;
            recovery_latency_s
                .extend(p.recovery_latency_s.iter().copied().filter(|v| v.is_finite()));
            n_migrations += p.n_migrations;
            migrated_tokens += p.migrated_tokens;
            migration_time_s += p.migration_time_s;
            makespan_s = makespan_s.max(p.makespan_s);
            ttft.extend_from_slice(&p.ttft_samples);
            tbt.extend_from_slice(&p.tbt_samples);
            e2e.extend_from_slice(&p.e2e_samples);
        }
        let mut report = Report::from_samples(
            label,
            n_requests,
            n_finished,
            n_output_tokens,
            makespan_s,
            ttft,
            tbt,
            e2e,
        );
        report.n_rejected = n_rejected;
        report.n_kv_hits = n_kv_hits;
        report.prefill_tokens_saved = prefill_tokens_saved;
        report.n_prefix_routed = n_prefix_routed;
        report.n_scale_ups = n_scale_ups;
        report.n_scale_downs = n_scale_downs;
        report.n_pair_failures = n_pair_failures;
        report.n_retries = n_retries;
        report.n_recovered = n_recovered;
        recovery_latency_s.sort_unstable_by(f64::total_cmp);
        report.recovery_latency_s = recovery_latency_s;
        report.n_migrations = n_migrations;
        report.migrated_tokens = migrated_tokens;
        report.migration_time_s = migration_time_s;
        report.classes = Self::merge_classes(parts);
        // The per-pair parts of a cluster run carry no KV accounting
        // (the router owns it; the cluster stamps hits + denominator
        // after merging), but merging *cluster-level* reports keeps the
        // rate consistent with the summed hits.
        report.kv_hit_rate = if n_prefix_routed > 0 {
            n_kv_hits as f64 / n_prefix_routed as f64
        } else {
            0.0
        };
        report
    }

    /// Merge the parts' per-class breakdowns by class name (first-seen
    /// order, which is class-id order when the parts share a registry),
    /// recomputing per-class percentiles over the union of raw samples.
    /// The merged run's makespan scales every class's throughput.
    fn merge_classes(parts: &[Report]) -> Vec<ClassBreakdown> {
        let makespan_s = parts.iter().fold(0.0f64, |m, p| m.max(p.makespan_s));
        let mut order: Vec<String> = Vec::new();
        for p in parts {
            for c in &p.classes {
                if !order.iter().any(|n| n == &c.name) {
                    order.push(c.name.clone());
                }
            }
        }
        order
            .into_iter()
            .map(|name| {
                let (mut n_req, mut n_fin, mut n_shed) = (0usize, 0usize, 0usize);
                let mut n_retries = 0usize;
                let mut ttft = Vec::new();
                let mut tbt = Vec::new();
                for p in parts {
                    for c in p.classes.iter().filter(|c| c.name == name) {
                        n_req += c.n_requests;
                        n_fin += c.n_finished;
                        n_shed += c.n_shed;
                        n_retries += c.n_retries;
                        ttft.extend_from_slice(&c.ttft_samples);
                        tbt.extend_from_slice(&c.tbt_samples);
                    }
                }
                let mut merged = ClassBreakdown::from_samples(
                    name, n_req, n_fin, n_shed, makespan_s, ttft, tbt,
                );
                merged.n_retries = n_retries;
                merged
            })
            .collect()
    }

    /// One-line summary used by benches and examples (plus one indented
    /// line per service class when a QoS breakdown is present).
    pub fn summary(&self) -> String {
        let mut s = format!(
            "{:<14} {:>5}/{:<5} reqs  thpt {:>6.2} req/s ({:>7.0} tok/s)  \
             TTFT p99 {:>7.3}s  TBT p99 {:>7.4}s  makespan {:>8.2}s",
            self.label,
            self.n_finished,
            self.n_requests,
            self.throughput_rps,
            self.token_throughput_tps,
            self.ttft_p99_s,
            self.tbt_p99_s,
            self.makespan_s
        );
        if self.n_rejected > 0 {
            s.push_str(&format!("  shed {}", self.n_rejected));
        }
        if self.n_kv_hits > 0 {
            s.push_str(&format!(
                "  kv-hit {:.0}% (saved {} tok)",
                100.0 * self.kv_hit_rate,
                self.prefill_tokens_saved
            ));
        }
        if self.n_scale_ups + self.n_scale_downs > 0 {
            s.push_str(&format!(
                "  scale +{}/-{}",
                self.n_scale_ups, self.n_scale_downs
            ));
        }
        if self.n_pair_failures > 0 {
            s.push_str(&format!(
                "  faults {} (retried {}, recovered {})",
                self.n_pair_failures, self.n_retries, self.n_recovered
            ));
        }
        if self.n_migrations > 0 {
            s.push_str(&format!(
                "  migrated {} ({} tok, {:.3}s link)",
                self.n_migrations, self.migrated_tokens, self.migration_time_s
            ));
        }
        for c in &self.classes {
            s.push_str(&format!(
                "\n    class {:<12} {:>5}/{:<5} reqs  thpt {:>6.2} req/s  \
                 TTFT p99 {:>7.3}s  TBT p99 {:>7.4}s",
                c.name,
                c.n_finished,
                c.n_requests,
                c.throughput_rps,
                c.ttft_p99_s,
                c.tbt_p99_s
            ));
            if c.n_shed > 0 {
                s.push_str(&format!("  shed {}", c.n_shed));
            }
            if c.n_retries > 0 {
                s.push_str(&format!("  retried {}", c.n_retries));
            }
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(s: f64) -> SimTime {
        SimTime::from_secs_f64(s)
    }

    #[test]
    fn ttft_and_tbt_bookkeeping() {
        let mut c = Collector::new();
        c.on_arrival(1, t(1.0));
        c.on_token(1, t(1.5)); // TTFT = 0.5
        c.on_token(1, t(1.6)); // gap 0.1
        c.on_token(1, t(1.8)); // gap 0.2
        c.on_finish(1, t(1.8));
        let r = c.report("x");
        assert!((r.ttft_p99_s - 0.5).abs() < 1e-9);
        assert!((r.tbt_mean_s - 0.15).abs() < 1e-9);
        assert_eq!(r.n_finished, 1);
    }

    #[test]
    fn throughput_uses_makespan() {
        let mut c = Collector::new();
        for i in 0..10 {
            c.on_arrival(i, SimTime::ZERO);
            c.on_token(i, t(0.5));
            c.on_finish(i, t(2.0));
        }
        let r = c.report("x");
        assert!((r.throughput_rps - 5.0).abs() < 1e-9);
        assert_eq!(r.makespan_s, 2.0);
    }

    #[test]
    fn unfinished_requests_counted_separately() {
        let mut c = Collector::new();
        c.on_arrival(1, SimTime::ZERO);
        c.on_arrival(2, SimTime::ZERO);
        c.on_token(1, t(1.0));
        c.on_finish(1, t(1.0));
        let r = c.report("x");
        assert_eq!(r.n_requests, 2);
        assert_eq!(r.n_finished, 1);
    }

    #[test]
    fn p99_separates_tail() {
        let mut c = Collector::new();
        // 95 fast requests + 5 slow ones (p99 rank 98.01 interpolates
        // inside the slow cluster).
        for i in 0..100 {
            c.on_arrival(i, SimTime::ZERO);
            let ttft = if i >= 95 { 10.0 } else { 0.1 };
            c.on_token(i, t(ttft));
            c.on_finish(i, t(ttft));
        }
        let r = c.report("x");
        assert!(r.ttft_p99_s > 5.0, "p99 {}", r.ttft_p99_s);
        assert!(r.ttft_p50_s < 0.2);
    }

    #[test]
    fn token_throughput() {
        let mut c = Collector::new();
        c.on_arrival(1, SimTime::ZERO);
        for k in 1..=10 {
            c.on_token(1, t(k as f64 * 0.1));
        }
        c.on_finish(1, t(1.0));
        let r = c.report("x");
        assert!((r.token_throughput_tps - 10.0).abs() < 1e-6);
    }

    #[test]
    fn summary_contains_label() {
        let mut c = Collector::new();
        c.on_arrival(1, SimTime::ZERO);
        c.on_token(1, t(0.1));
        c.on_finish(1, t(0.2));
        assert!(c.report("cronus").summary().contains("cronus"));
    }

    #[test]
    fn report_carries_raw_samples() {
        let mut c = Collector::new();
        c.on_arrival(1, t(1.0));
        c.on_token(1, t(1.5));
        c.on_token(1, t(1.7));
        c.on_finish(1, t(1.7));
        let r = c.report("x");
        assert_eq!(r.ttft_samples, vec![0.5]);
        assert_eq!(r.tbt_samples.len(), 1);
        assert_eq!(r.e2e_samples.len(), 1);
        assert_eq!(r.n_output_tokens, 2);
    }

    #[test]
    fn merge_recomputes_percentiles_over_union() {
        // Instance A: 9 fast requests; instance B: 1 slow one.  The
        // merged p99 must see B's tail even though B's own p99 is its
        // only sample.
        let mut a = Collector::new();
        for i in 0..9 {
            a.on_arrival(i, SimTime::ZERO);
            a.on_token(i, t(0.1));
            a.on_finish(i, t(0.1));
        }
        let mut b = Collector::new();
        b.on_arrival(100, SimTime::ZERO);
        b.on_token(100, t(4.0));
        b.on_finish(100, t(5.0));
        let merged = Report::merge("cluster", &[a.report("a"), b.report("b")]);
        assert_eq!(merged.n_requests, 10);
        assert_eq!(merged.n_finished, 10);
        assert_eq!(merged.makespan_s, 5.0);
        assert!((merged.throughput_rps - 2.0).abs() < 1e-9);
        assert!(merged.ttft_p99_s > 3.0, "p99 {}", merged.ttft_p99_s);
        assert!(merged.ttft_p50_s < 0.2);
        assert_eq!(merged.ttft_samples.len(), 10);
    }

    #[test]
    fn shed_requests_surface_in_report_and_merge() {
        let mut c = Collector::new();
        c.on_arrival(1, SimTime::ZERO);
        c.on_shed(1);
        c.on_arrival(2, SimTime::ZERO);
        c.on_token(2, t(0.5));
        c.on_finish(2, t(0.5));
        let r = c.report("x");
        assert_eq!(r.n_requests, 2);
        assert_eq!(r.n_finished, 1);
        assert_eq!(r.n_rejected, 1);
        assert!(r.summary().contains("shed 1"), "{}", r.summary());
        let merged = Report::merge("m", &[r.clone(), r]);
        assert_eq!(merged.n_rejected, 2);
    }

    #[test]
    fn from_samples_sorts_once_and_matches_clone_sort_percentiles() {
        let raw = vec![3.0, 1.0, 2.0, 10.0, 0.5];
        let r = Report::from_samples(
            "x",
            5,
            5,
            5,
            1.0,
            raw.clone(),
            Vec::new(),
            Vec::new(),
        );
        let mut sorted = raw.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert_eq!(r.ttft_samples, sorted, "samples retained sorted");
        assert_eq!(r.ttft_p50_s, crate::util::stats::percentile(&raw, 50.0));
        assert_eq!(r.ttft_p99_s, crate::util::stats::percentile(&raw, 99.0));
    }

    #[test]
    fn kv_hits_merge_and_surface_in_summary() {
        let mut c = Collector::new();
        c.on_arrival(1, SimTime::ZERO);
        c.on_token(1, t(0.1));
        c.on_finish(1, t(0.2));
        let mut r = c.report("x");
        assert_eq!(r.n_kv_hits, 0);
        assert!(!r.summary().contains("kv-hit"));
        r.n_kv_hits = 3;
        r.prefill_tokens_saved = 1200;
        r.n_prefix_routed = 4;
        r.kv_hit_rate = 0.75;
        assert!(r.summary().contains("kv-hit 75%"), "{}", r.summary());
        assert!(r.summary().contains("saved 1200 tok"), "{}", r.summary());
        let merged = Report::merge("m", &[r.clone(), r]);
        assert_eq!(merged.n_kv_hits, 6);
        assert_eq!(merged.prefill_tokens_saved, 2400);
        // The denominator merges too, so the merged rate stays
        // consistent with the summed hits (it used to reset to 0%).
        assert_eq!(merged.n_prefix_routed, 8);
        assert!((merged.kv_hit_rate - 0.75).abs() < 1e-12);
        assert!(merged.summary().contains("kv-hit 75%"), "{}", merged.summary());
    }

    #[test]
    fn scale_counters_merge_and_surface_in_summary() {
        let mut c = Collector::new();
        c.on_arrival(1, SimTime::ZERO);
        c.on_token(1, t(0.1));
        c.on_finish(1, t(0.2));
        let mut r = c.report("x");
        assert_eq!((r.n_scale_ups, r.n_scale_downs), (0, 0));
        assert!(!r.summary().contains("scale"));
        r.n_scale_ups = 3;
        r.n_scale_downs = 2;
        assert!(r.summary().contains("scale +3/-2"), "{}", r.summary());
        let merged = Report::merge("m", &[r.clone(), r]);
        assert_eq!(merged.n_scale_ups, 6);
        assert_eq!(merged.n_scale_downs, 4);
    }

    #[test]
    fn fault_counters_merge_and_surface_in_summary() {
        let mut c = Collector::new();
        c.on_arrival(1, SimTime::ZERO);
        c.on_token(1, t(0.1));
        c.on_finish(1, t(0.2));
        let mut r = c.report("x");
        assert_eq!((r.n_pair_failures, r.n_retries, r.n_recovered), (0, 0, 0));
        assert!(r.recovery_latency_s.is_empty());
        assert!(!r.summary().contains("faults"));
        r.n_pair_failures = 2;
        r.n_retries = 5;
        r.n_recovered = 1;
        r.recovery_latency_s = vec![0.8];
        let s = r.summary();
        assert!(s.contains("faults 2 (retried 5, recovered 1)"), "{s}");
        let merged = Report::merge("m", &[r.clone(), r]);
        assert_eq!(merged.n_pair_failures, 4);
        assert_eq!(merged.n_retries, 10);
        assert_eq!(merged.n_recovered, 2);
        assert_eq!(merged.recovery_latency_s, vec![0.8, 0.8]);
    }

    #[test]
    fn migration_counters_merge_and_surface_in_summary() {
        let mut c = Collector::new();
        c.on_arrival(1, SimTime::ZERO);
        c.on_token(1, t(0.1));
        c.on_finish(1, t(0.2));
        let mut r = c.report("x");
        assert_eq!((r.n_migrations, r.migrated_tokens), (0, 0));
        assert_eq!(r.migration_time_s, 0.0);
        assert!(!r.summary().contains("migrated"));
        r.n_migrations = 2;
        r.migrated_tokens = 1800;
        r.migration_time_s = 0.025;
        assert!(
            r.summary().contains("migrated 2 (1800 tok, 0.025s link)"),
            "{}",
            r.summary()
        );
        let merged = Report::merge("m", &[r.clone(), r]);
        assert_eq!(merged.n_migrations, 4);
        assert_eq!(merged.migrated_tokens, 3600);
        assert!((merged.migration_time_s - 0.05).abs() < 1e-12);
    }

    #[test]
    fn non_finite_samples_are_rejected_not_panicked_on() {
        // A NaN used to panic the `partial_cmp(..).unwrap()` sorts in
        // from_samples and merge; now non-finite samples are rejected at
        // insertion and the sorts are total.
        let r = Report::from_samples(
            "x",
            4,
            4,
            4,
            1.0,
            vec![0.2, f64::NAN, 0.1, f64::INFINITY],
            vec![f64::NAN],
            vec![f64::NEG_INFINITY, 0.5],
        );
        assert_eq!(r.ttft_samples, vec![0.1, 0.2]);
        assert!(r.tbt_samples.is_empty());
        assert_eq!(r.e2e_samples, vec![0.5]);
        let c = ClassBreakdown::from_samples(
            "premium",
            2,
            2,
            0,
            1.0,
            vec![f64::NAN, 0.3],
            vec![0.01, f64::INFINITY],
        );
        assert_eq!(c.ttft_samples, vec![0.3]);
        assert_eq!(c.tbt_samples, vec![0.01]);
        let mut faulty = r.clone();
        faulty.recovery_latency_s = vec![0.8, f64::NAN];
        let merged = Report::merge("m", &[faulty.clone(), faulty]);
        assert_eq!(merged.recovery_latency_s, vec![0.8, 0.8]);
    }

    #[test]
    fn fault_abort_forgets_inflight_but_keeps_terminal_records() {
        let mut c = Collector::new();
        // 1 finishes, 2 is shed, 3 and 4 are mid-flight.
        c.on_arrival(1, SimTime::ZERO);
        c.on_token(1, t(0.1));
        c.on_finish(1, t(0.2));
        c.on_arrival(2, SimTime::ZERO);
        c.on_shed(2);
        c.on_arrival(4, SimTime::ZERO);
        c.on_arrival(3, SimTime::ZERO);
        c.on_token(3, t(0.1));
        let dropped = c.drop_unfinished();
        assert_eq!(dropped, vec![3, 4], "sorted, terminal records spared");
        let r = c.report("x");
        assert_eq!(r.n_requests, 2);
        assert_eq!(r.n_finished, 1);
        assert_eq!(r.n_rejected, 1);
        // The aborted requests left no latency samples behind.
        assert_eq!(r.ttft_samples.len(), 1);
        // The ids can arrive again (re-submission to the same pair).
        c.on_arrival(3, t(1.0));
        c.forget(3);
        assert_eq!(c.n_arrived(), 2);
    }

    #[test]
    fn class_retries_merge_and_surface_in_summary() {
        let mut a =
            ClassBreakdown::from_samples("premium", 3, 3, 0, 1.0, vec![0.1], vec![]);
        a.n_retries = 2;
        let mut r = Report::from_samples("x", 3, 3, 3, 1.0, vec![], vec![], vec![]);
        r.classes = vec![a];
        assert!(r.summary().contains("retried 2"), "{}", r.summary());
        let merged = Report::merge("m", &[r.clone(), r]);
        assert_eq!(merged.classes[0].n_retries, 4);
    }

    #[test]
    fn merge_of_nothing_is_empty() {
        let r = Report::merge("empty", &[]);
        assert_eq!(r.n_requests, 0);
        assert_eq!(r.throughput_rps, 0.0);
        assert_eq!(r.ttft_p99_s, 0.0);
        assert!(r.classes.is_empty());
    }

    #[test]
    fn class_breakdowns_merge_by_name_and_surface_in_summary() {
        let mut c = Collector::new();
        c.on_arrival(1, SimTime::ZERO);
        c.on_token(1, t(0.1));
        c.on_finish(1, t(0.2));
        let mut a = c.report("a");
        a.classes = vec![
            ClassBreakdown::from_samples("premium", 2, 2, 0, 2.0, vec![0.1, 0.3], vec![0.01]),
            ClassBreakdown::from_samples("batch", 3, 2, 1, 2.0, vec![0.5, 0.9], vec![0.02]),
        ];
        let mut b = a.clone();
        b.label = "b".into();
        // Part b saw only the batch class, with a worse tail.
        b.classes = vec![ClassBreakdown::from_samples(
            "batch",
            1,
            1,
            0,
            4.0,
            vec![2.0],
            vec![0.04],
        )];
        b.makespan_s = 4.0;
        let merged = Report::merge("m", &[a, b]);
        assert_eq!(merged.classes.len(), 2);
        let premium = &merged.classes[0];
        assert_eq!((premium.name.as_str(), premium.n_requests), ("premium", 2));
        let batch = &merged.classes[1];
        assert_eq!(batch.n_requests, 4);
        assert_eq!(batch.n_finished, 3);
        assert_eq!(batch.n_shed, 1);
        assert_eq!(batch.ttft_samples, vec![0.5, 0.9, 2.0]);
        assert!(batch.ttft_p99_s > 1.9, "merged tail must see part b");
        // Throughput rescales to the merged makespan (4s).
        assert!((premium.throughput_rps - 0.5).abs() < 1e-12);
        let s = merged.summary();
        assert!(s.contains("class premium"), "{s}");
        assert!(s.contains("class batch"), "{s}");
        assert!(s.contains("shed 1"), "{s}");
    }
}
