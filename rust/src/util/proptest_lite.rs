//! A tiny property-testing harness (the image ships no `proptest`).
//!
//! Usage mirrors the proptest idiom at a smaller scale: a property is a
//! closure taking a seeded [`Rng`]; the runner executes it for many
//! deterministic seeds and reports the first failing seed so failures
//! reproduce exactly:
//!
//! ```
//! use cronus::util::proptest_lite::{check, PropResult};
//! check("sum is commutative", 100, |rng| {
//!     let a = rng.range(0, 1000) as i64;
//!     let b = rng.range(0, 1000) as i64;
//!     PropResult::assert_eq("a+b == b+a", a + b, b + a)
//! });
//! ```

use crate::util::rng::Rng;

/// Outcome of a single property case.
#[must_use]
pub enum PropResult {
    Ok,
    Fail(String),
    /// The generated input didn't meet the property's precondition.
    Discard,
}

impl PropResult {
    pub fn assert_true(what: &str, cond: bool) -> PropResult {
        if cond {
            PropResult::Ok
        } else {
            PropResult::Fail(format!("assertion failed: {what}"))
        }
    }

    pub fn assert_eq<T: PartialEq + std::fmt::Debug>(
        what: &str,
        a: T,
        b: T,
    ) -> PropResult {
        if a == b {
            PropResult::Ok
        } else {
            PropResult::Fail(format!("{what}: {a:?} != {b:?}"))
        }
    }

    /// Chain: first failure wins.
    pub fn and(self, next: impl FnOnce() -> PropResult) -> PropResult {
        match self {
            PropResult::Ok => next(),
            other => other,
        }
    }
}

/// Run `cases` deterministic cases of the property; panics (with the
/// failing seed) on the first failure.  Base seed is derived from the
/// property name so distinct properties explore distinct streams.
pub fn check<F>(name: &str, cases: u32, mut prop: F)
where
    F: FnMut(&mut Rng) -> PropResult,
{
    let base = name_seed(name);
    let mut discards = 0u32;
    let mut ran = 0u32;
    let mut case = 0u32;
    // Allow up to 10x discards before giving up on the precondition.
    while ran < cases && discards < cases.saturating_mul(10) {
        let seed = base ^ (case as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let mut rng = Rng::new(seed);
        match prop(&mut rng) {
            PropResult::Ok => ran += 1,
            PropResult::Discard => discards += 1,
            PropResult::Fail(msg) => panic!(
                "property '{name}' failed on case {case} (seed {seed:#x}): {msg}"
            ),
        }
        case += 1;
    }
    assert!(
        ran >= cases.min(1),
        "property '{name}': too many discards ({discards}) — precondition too strict"
    );
}

/// FNV-1a of the property name — stable across runs and platforms.
fn name_seed(name: &str) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in name.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x1000_0000_01b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        check("trivially true", 50, |_| {
            count += 1;
            PropResult::Ok
        });
        assert_eq!(count, 50);
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn failing_property_panics_with_name() {
        check("always fails", 10, |_| {
            PropResult::Fail("nope".into())
        });
    }

    #[test]
    fn discards_are_tolerated() {
        check("half discarded", 20, |rng| {
            if rng.f64() < 0.5 {
                PropResult::Discard
            } else {
                PropResult::Ok
            }
        });
    }

    #[test]
    #[should_panic(expected = "too many discards")]
    fn all_discards_panics() {
        check("all discarded", 10, |_| PropResult::Discard);
    }

    #[test]
    fn seeds_are_deterministic() {
        let mut first: Vec<u64> = Vec::new();
        check("collect", 5, |rng| {
            first.push(rng.next_u64());
            PropResult::Ok
        });
        let mut second: Vec<u64> = Vec::new();
        check("collect", 5, |rng| {
            second.push(rng.next_u64());
            PropResult::Ok
        });
        assert_eq!(first, second);
    }

    #[test]
    fn and_chains_results() {
        let r = PropResult::assert_true("a", true)
            .and(|| PropResult::assert_eq("b", 1, 1));
        assert!(matches!(r, PropResult::Ok));
        let r = PropResult::assert_true("a", false)
            .and(|| PropResult::assert_eq("b", 1, 2));
        match r {
            PropResult::Fail(msg) => assert!(msg.contains("a")),
            _ => panic!("expected failure"),
        }
    }
}
