//! Minimal JSON parser — just enough for `artifacts/manifest.json`.
//!
//! Supports the full JSON grammar minus exotic number forms (it accepts
//! everything Rust's `f64::from_str` accepts after standard JSON number
//! lexing) and `\uXXXX` escapes (decoded, surrogate pairs included).
//! No serialization; the Rust side only ever reads manifests.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(BTreeMap<String, Value>),
}

impl Value {
    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(map) => map.get(key),
            _ => None,
        }
    }

    /// `obj["a"]["b"][...]` chained access; returns Null-ish None on miss.
    pub fn path(&self, keys: &[&str]) -> Option<&Value> {
        let mut cur = self;
        for k in keys {
            cur = cur.get(k)?;
        }
        Some(cur)
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(xs) => Some(xs),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

#[derive(Debug)]
pub struct ParseError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for ParseError {}

pub fn parse(input: &str) -> Result<Value, ParseError> {
    let mut p = Parser { bytes: input.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(p.err("trailing data"));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl Parser<'_> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), ParseError> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            self.pos = self.pos.saturating_sub(1);
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, word: &str, v: Value) -> Result<Value, ParseError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn value(&mut self) -> Result<Value, ParseError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.literal("true", Value::Bool(true)),
            Some(b'f') => self.literal("false", Value::Bool(false)),
            Some(b'n') => self.literal("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn object(&mut self) -> Result<Value, ParseError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Value::Obj(map)),
                _ => {
                    self.pos = self.pos.saturating_sub(1);
                    return Err(self.err("expected ',' or '}'"));
                }
            }
        }
    }

    fn array(&mut self) -> Result<Value, ParseError> {
        self.expect(b'[')?;
        let mut xs = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Arr(xs));
        }
        loop {
            self.skip_ws();
            xs.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Value::Arr(xs)),
                _ => {
                    self.pos = self.pos.saturating_sub(1);
                    return Err(self.err("expected ',' or ']'"));
                }
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let hi = self.hex4()?;
                        let cp = if (0xD800..0xDC00).contains(&hi) {
                            // surrogate pair
                            self.expect(b'\\')?;
                            self.expect(b'u')?;
                            let lo = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                return Err(self.err("bad low surrogate"));
                            }
                            0x10000 + ((hi - 0xD800) << 10) + (lo - 0xDC00)
                        } else {
                            hi
                        };
                        out.push(
                            char::from_u32(cp)
                                .ok_or_else(|| self.err("bad codepoint"))?,
                        );
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x20 => {
                    return Err(self.err("control char in string"))
                }
                Some(c) => {
                    // Re-assemble UTF-8 multibyte sequences verbatim.
                    if c < 0x80 {
                        out.push(c as char);
                    } else {
                        let start = self.pos - 1;
                        let len = utf8_len(c);
                        let end = start + len;
                        if end > self.bytes.len() {
                            return Err(self.err("truncated utf-8"));
                        }
                        let s = std::str::from_utf8(&self.bytes[start..end])
                            .map_err(|_| self.err("invalid utf-8"))?;
                        out.push_str(s);
                        self.pos = end;
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, ParseError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.bump().ok_or_else(|| self.err("eof in \\u"))?;
            let d = (c as char)
                .to_digit(16)
                .ok_or_else(|| self.err("bad hex digit"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Value, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Value::Num)
            .map_err(|_| self.err("bad number"))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        0xF0..=0xF7 => 4,
        _ => 1,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(parse("null").unwrap(), Value::Null);
        assert_eq!(parse("true").unwrap(), Value::Bool(true));
        assert_eq!(parse("false").unwrap(), Value::Bool(false));
        assert_eq!(parse("42").unwrap(), Value::Num(42.0));
        assert_eq!(parse("-1.5e3").unwrap(), Value::Num(-1500.0));
        assert_eq!(parse("\"hi\"").unwrap(), Value::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.path(&["c"]).unwrap().as_str(), Some("x"));
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[1].as_f64(), Some(2.0));
        assert_eq!(arr[2].get("b"), Some(&Value::Null));
    }

    #[test]
    fn parses_escapes() {
        let v = parse(r#""a\nb\t\"\\ A 😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\nb\t\"\\ A 😀");
    }

    #[test]
    fn parses_unicode_passthrough() {
        let v = parse("\"héllo — ok\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo — ok");
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("").is_err());
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("{\"a\" 1}").is_err());
        assert!(parse("nul").is_err());
        assert!(parse("1 2").is_err());
        assert!(parse("\"\\q\"").is_err());
    }

    #[test]
    fn whitespace_tolerant() {
        let v = parse(" \n\t{ \"a\" : [ ] , \"b\" : { } } ").unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 0);
        assert!(matches!(v.get("b"), Some(Value::Obj(_))));
    }

    #[test]
    fn empty_containers() {
        assert_eq!(parse("[]").unwrap(), Value::Arr(vec![]));
        assert_eq!(parse("{}").unwrap(), Value::Obj(BTreeMap::new()));
    }

    #[test]
    fn parses_real_manifest_shape() {
        let text = r#"{
          "format_version": 1,
          "model": {"name": "tiny-llama", "n_layers": 4},
          "params": [{"name": "embed", "shape": [2048, 256], "offset_bytes": 0}],
          "entries": {"prefill": {"file": "prefill_c64.hlo.txt", "chunk": 64}}
        }"#;
        let v = parse(text).unwrap();
        assert_eq!(v.path(&["model", "n_layers"]).unwrap().as_usize(), Some(4));
        assert_eq!(
            v.path(&["entries", "prefill", "chunk"]).unwrap().as_usize(),
            Some(64)
        );
        let shape = v.get("params").unwrap().as_arr().unwrap()[0]
            .get("shape")
            .unwrap()
            .as_arr()
            .unwrap();
        assert_eq!(shape[0].as_usize(), Some(2048));
    }
}
