//! Small self-contained substrates the rest of the stack builds on.
//!
//! The offline build environment ships no `rand`, `serde`, `criterion` or
//! `proptest`, so this module provides the pieces we need from scratch
//! (documented as substitutions in DESIGN.md §1):
//!
//! * [`rng`] — deterministic PRNG (SplitMix64 / xoshiro256++) with the
//!   distributions the workload generator needs.
//! * [`stats`] — exact percentiles, ordinary least squares (the paper's
//!   Eq. 2/3 fits), R², MAPE.
//! * [`json`] — a minimal JSON parser for `artifacts/manifest.json`.
//! * [`error`] — an `anyhow` substitute (`Error`, `Result`, `Context`,
//!   the `anyhow!`/`bail!` macros) for the runtime/server layers.
//! * [`proptest_lite`] — a tiny property-testing harness used by the
//!   invariant tests.
//! * [`fxhash`] — a fast non-cryptographic hasher for the hot maps.

pub mod error;
pub mod fxhash;
pub mod json;
pub mod proptest_lite;
pub mod rng;
pub mod stats;
