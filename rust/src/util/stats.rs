//! Statistics utilities: exact percentiles and least-squares fits.
//!
//! The paper models execution times with linear regressions (Eq. 2 for
//! partial prefill, Eq. 3 for chunked-prefill iterations) and reports the
//! fits' R² and mean-absolute-percentage-error; [`ols`] reproduces that
//! machinery (normal equations + Gaussian elimination, fine for the 2–3
//! feature fits we need).  Percentiles use the nearest-rank-with-linear-
//! interpolation definition (matches numpy's default).

/// Arithmetic mean; 0.0 for an empty slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population variance.
pub fn variance(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let m = mean(xs);
    xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64
}

/// Percentile `p` in `[0, 100]` with linear interpolation between ranks.
/// Returns 0.0 for an empty slice (callers treat that as "no data").
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut sorted: Vec<f64> = xs.to_vec();
    sorted.sort_by(f64::total_cmp);
    percentile_of_sorted(&sorted, p)
}

/// Percentile of an already-sorted slice.
pub fn percentile_of_sorted(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    if sorted.len() == 1 {
        return sorted[0];
    }
    let p = p.clamp(0.0, 100.0);
    let rank = p / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let w = rank - lo as f64;
        sorted[lo] * (1.0 - w) + sorted[hi] * w
    }
}

/// Result of an ordinary-least-squares fit `y ≈ X·beta` (intercept last).
#[derive(Clone, Debug)]
pub struct Fit {
    /// Coefficients, one per feature, followed by the intercept.
    pub beta: Vec<f64>,
    /// Coefficient of determination.
    pub r2: f64,
    /// Mean absolute percentage error (fraction, not %).
    pub mape: f64,
}

impl Fit {
    pub fn predict(&self, features: &[f64]) -> f64 {
        debug_assert_eq!(features.len() + 1, self.beta.len());
        features
            .iter()
            .zip(&self.beta)
            .map(|(x, b)| x * b)
            .sum::<f64>()
            + self.beta[self.beta.len() - 1]
    }
}

/// OLS with intercept.  `rows[i]` is the feature vector for sample `i`.
/// Solves the (k+1)×(k+1) normal equations by Gaussian elimination with
/// partial pivoting — exact enough for the paper's 1–2 feature fits.
pub fn ols(rows: &[Vec<f64>], ys: &[f64]) -> Option<Fit> {
    let n = rows.len();
    if n == 0 || n != ys.len() {
        return None;
    }
    let k = rows[0].len();
    let dim = k + 1;
    if n < dim {
        return None;
    }
    // Build X^T X and X^T y with the implicit trailing 1-column.
    let feat = |row: &Vec<f64>, j: usize| if j < k { row[j] } else { 1.0 };
    let mut a = vec![vec![0.0; dim]; dim];
    let mut b = vec![0.0; dim];
    for (row, &y) in rows.iter().zip(ys) {
        debug_assert_eq!(row.len(), k);
        for i in 0..dim {
            let xi = feat(row, i);
            b[i] += xi * y;
            for j in 0..dim {
                a[i][j] += xi * feat(row, j);
            }
        }
    }
    let beta = solve(&mut a, &mut b)?;
    // Goodness of fit.
    let y_mean = mean(ys);
    let mut ss_res = 0.0;
    let mut ss_tot = 0.0;
    let mut mape_sum = 0.0;
    let mut mape_n = 0usize;
    for (row, &y) in rows.iter().zip(ys) {
        let pred: f64 =
            (0..dim).map(|j| beta[j] * feat(row, j)).sum::<f64>();
        ss_res += (y - pred) * (y - pred);
        ss_tot += (y - y_mean) * (y - y_mean);
        if y.abs() > 1e-12 {
            mape_sum += ((y - pred) / y).abs();
            mape_n += 1;
        }
    }
    let r2 = if ss_tot > 0.0 { 1.0 - ss_res / ss_tot } else { 1.0 };
    let mape = if mape_n > 0 { mape_sum / mape_n as f64 } else { 0.0 };
    Some(Fit { beta, r2, mape })
}

/// Gaussian elimination with partial pivoting; `a` and `b` are clobbered.
fn solve(a: &mut [Vec<f64>], b: &mut [f64]) -> Option<Vec<f64>> {
    let n = b.len();
    for col in 0..n {
        // Pivot.
        let pivot = (col..n).max_by(|&i, &j| {
            a[i][col].abs().partial_cmp(&a[j][col].abs()).unwrap()
        })?;
        if a[pivot][col].abs() < 1e-12 {
            return None; // singular
        }
        a.swap(col, pivot);
        b.swap(col, pivot);
        for row in (col + 1)..n {
            let f = a[row][col] / a[col][col];
            for j in col..n {
                a[row][j] -= f * a[col][j];
            }
            b[row] -= f * b[col];
        }
    }
    let mut x = vec![0.0; n];
    for row in (0..n).rev() {
        let mut acc = b[row];
        for j in (row + 1)..n {
            acc -= a[row][j] * x[j];
        }
        x[row] = acc / a[row][row];
    }
    Some(x)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn mean_and_variance() {
        assert_eq!(mean(&[1.0, 2.0, 3.0]), 2.0);
        assert!((variance(&[1.0, 2.0, 3.0]) - 2.0 / 3.0).abs() < 1e-12);
        assert_eq!(mean(&[]), 0.0);
    }

    #[test]
    fn percentile_basics() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 5.0);
        assert_eq!(percentile(&xs, 50.0), 3.0);
        assert_eq!(percentile(&xs, 25.0), 2.0);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [0.0, 10.0];
        assert!((percentile(&xs, 99.0) - 9.9).abs() < 1e-9);
    }

    #[test]
    fn percentile_unsorted_input() {
        let xs = [5.0, 1.0, 3.0, 2.0, 4.0];
        assert_eq!(percentile(&xs, 50.0), 3.0);
    }

    #[test]
    fn percentile_empty_and_single() {
        assert_eq!(percentile(&[], 99.0), 0.0);
        assert_eq!(percentile(&[7.0], 1.0), 7.0);
    }

    #[test]
    fn ols_recovers_exact_line() {
        // y = 3x + 2
        let rows: Vec<Vec<f64>> = (0..10).map(|i| vec![i as f64]).collect();
        let ys: Vec<f64> = (0..10).map(|i| 3.0 * i as f64 + 2.0).collect();
        let fit = ols(&rows, &ys).unwrap();
        assert!((fit.beta[0] - 3.0).abs() < 1e-9);
        assert!((fit.beta[1] - 2.0).abs() < 1e-9);
        assert!((fit.r2 - 1.0).abs() < 1e-12);
        assert!(fit.mape < 1e-9);
    }

    #[test]
    fn ols_two_features() {
        // y = 2a - 0.5b + 7, exercised on a grid (mirrors Eq. 3's form).
        let mut rows = Vec::new();
        let mut ys = Vec::new();
        for a in 0..8 {
            for b in 0..8 {
                rows.push(vec![a as f64, b as f64]);
                ys.push(2.0 * a as f64 - 0.5 * b as f64 + 7.0);
            }
        }
        let fit = ols(&rows, &ys).unwrap();
        assert!((fit.beta[0] - 2.0).abs() < 1e-9);
        assert!((fit.beta[1] + 0.5).abs() < 1e-9);
        assert!((fit.beta[2] - 7.0).abs() < 1e-9);
    }

    #[test]
    fn ols_noisy_r2_high() {
        let mut rng = Rng::new(5);
        let rows: Vec<Vec<f64>> =
            (0..200).map(|_| vec![rng.f64() * 100.0]).collect();
        let ys: Vec<f64> = rows
            .iter()
            .map(|r| 1.5 * r[0] + 10.0 + rng.normal() * 0.5)
            .collect();
        let fit = ols(&rows, &ys).unwrap();
        assert!(fit.r2 > 0.99, "r2 {}", fit.r2);
        assert!((fit.beta[0] - 1.5).abs() < 0.05);
    }

    #[test]
    fn ols_rejects_underdetermined() {
        assert!(ols(&[vec![1.0, 2.0]], &[3.0]).is_none());
        assert!(ols(&[], &[]).is_none());
    }

    #[test]
    fn ols_rejects_singular() {
        // Feature identical to intercept -> singular normal equations.
        let rows: Vec<Vec<f64>> = (0..10).map(|_| vec![1.0]).collect();
        let ys: Vec<f64> = (0..10).map(|i| i as f64).collect();
        assert!(ols(&rows, &ys).is_none());
    }

    #[test]
    fn fit_predict_matches_formula() {
        let fit = Fit { beta: vec![2.0, -1.0, 5.0], r2: 1.0, mape: 0.0 };
        assert_eq!(fit.predict(&[3.0, 4.0]), 2.0 * 3.0 - 4.0 + 5.0);
    }
}
