//! Deterministic pseudo-random numbers for workload generation and tests.
//!
//! xoshiro256++ seeded via SplitMix64 — fast, high-quality, and fully
//! reproducible across runs, which the experiment harness depends on
//! (every bench records its seed).

/// xoshiro256++ PRNG.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a generator from a 64-bit seed (SplitMix64 state expansion).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next_sm = || {
            sm = sm.wrapping_add(0x9E37_79B9_7F4A_7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
            z ^ (z >> 31)
        };
        let s = [next_sm(), next_sm(), next_sm(), next_sm()];
        Rng { s }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let res = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        res
    }

    /// Uniform in `[0, 1)` with 53 bits of precision.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in `[lo, hi)`. Panics if `lo >= hi`.
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo < hi, "empty range [{lo}, {hi})");
        // Lemire's method (rejection-free enough for our span sizes).
        let span = hi - lo;
        lo + (((self.next_u64() as u128 * span as u128) >> 64) as u64)
    }

    /// Uniform usize in `[lo, hi)`.
    pub fn range_usize(&mut self, lo: usize, hi: usize) -> usize {
        self.range(lo as u64, hi as u64) as usize
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        // Avoid ln(0).
        let u1 = (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64;
        let u1 = if u1 <= 0.0 { f64::MIN_POSITIVE } else { u1 };
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Log-normal with the given *underlying* normal parameters.
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }

    /// Exponential with the given rate (mean `1/rate`).
    pub fn exponential(&mut self, rate: f64) -> f64 {
        let u = 1.0 - self.f64(); // (0, 1]
        -u.ln() / rate
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.range_usize(0, i + 1);
            xs.swap(i, j);
        }
    }

    /// Weighted index choice; weights must be non-negative, not all zero.
    pub fn choice_weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0, "all weights zero");
        let mut x = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            if x < *w {
                return i;
            }
            x -= w;
        }
        weights.len() - 1
    }
}

/// Parameters of a log-normal chosen to hit a target mean with the given
/// shape parameter sigma: `mu = ln(mean) - sigma^2 / 2`.
pub fn lognormal_mu_for_mean(mean: f64, sigma: f64) -> f64 {
    mean.ln() - sigma * sigma / 2.0
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn range_respects_bounds() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let x = r.range(10, 20);
            assert!((10..20).contains(&x));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn lognormal_targets_mean() {
        let mut r = Rng::new(13);
        let sigma = 0.9;
        let mu = lognormal_mu_for_mean(1014.0, sigma);
        let n = 200_000;
        let mean = (0..n).map(|_| r.lognormal(mu, sigma)).sum::<f64>() / n as f64;
        assert!(
            (mean - 1014.0).abs() / 1014.0 < 0.03,
            "lognormal mean {mean} (target 1014)"
        );
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::new(17);
        let n = 100_000;
        let mean = (0..n).map(|_| r.exponential(4.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.25).abs() < 0.01, "exp mean {mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(19);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn weighted_choice_distribution() {
        let mut r = Rng::new(23);
        let weights = [3.0, 1.0];
        let mut counts = [0usize; 2];
        for _ in 0..40_000 {
            counts[r.choice_weighted(&weights)] += 1;
        }
        let frac = counts[0] as f64 / 40_000.0;
        assert!((frac - 0.75).abs() < 0.02, "frac {frac}");
    }

    #[test]
    #[should_panic]
    fn empty_range_panics() {
        Rng::new(0).range(5, 5);
    }
}
