//! Minimal `anyhow` substitute (the offline build ships no `anyhow`).
//!
//! Provides the four pieces the runtime/server layers use: a boxed-string
//! [`Error`], a defaulted [`Result`] alias, the [`Context`] extension
//! trait, and the `anyhow!` / `bail!` macros (exported at the crate
//! root, as macros always are).  Like `anyhow::Error`, [`Error`] does
//! *not* implement `std::error::Error` itself so that the blanket
//! `From<E: std::error::Error>` conversion can exist without overlapping
//! the reflexive `From<Error>`.

use std::fmt;

/// A flattened error message (source chains are rendered eagerly).
#[derive(Clone)]
pub struct Error(String);

impl Error {
    pub fn msg(msg: impl fmt::Display) -> Error {
        Error(msg.to_string())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error(e.to_string())
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// `anyhow::Context` stand-in: annotate errors (or `None`) with context.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| Error(format!("{ctx}: {e}")))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error(format!("{}: {e}", f())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.ok_or_else(|| Error(ctx.to_string()))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error(f().to_string()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::util::error::Error::msg(format!($($arg)*))
    };
}

/// Early-return an `Err` built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<()> {
        std::fs::read("/definitely/not/a/path/xyz")?;
        Ok(())
    }

    #[test]
    fn question_mark_converts_std_errors() {
        let e = io_fail().unwrap_err();
        assert!(!e.to_string().is_empty());
    }

    #[test]
    fn context_annotates() {
        let r: std::result::Result<(), std::fmt::Error> = Err(std::fmt::Error);
        let e = r.context("doing a thing").unwrap_err();
        assert!(e.to_string().starts_with("doing a thing: "));
        let n: Option<u32> = None;
        let e = n.with_context(|| format!("missing {}", "key")).unwrap_err();
        assert_eq!(e.to_string(), "missing key");
    }

    #[test]
    fn macros_format() {
        let e = anyhow!("bad value {}", 7);
        assert_eq!(e.to_string(), "bad value 7");
        fn f() -> Result<()> {
            bail!("nope: {}", "reason");
        }
        assert_eq!(f().unwrap_err().to_string(), "nope: reason");
    }
}
