//! A fast non-cryptographic hasher for the scheduler's hot maps
//! (rustc-hash's multiply-xor construction).  Request-id keys are small
//! integers under our control, so HashDoS resistance buys nothing and
//! SipHash costs ~3x per lookup on the engine's per-iteration paths
//! (EXPERIMENTS.md §Perf).

use std::hash::{BuildHasherDefault, Hasher};

const SEED: u64 = 0x51_7c_c1_b7_27_22_0a_95;

/// rustc-hash-style hasher: rotate, xor, multiply per word.
#[derive(Default)]
pub struct FxHasher {
    hash: u64,
}

impl FxHasher {
    #[inline]
    fn add_to_hash(&mut self, i: u64) {
        self.hash = (self.hash.rotate_left(5) ^ i).wrapping_mul(SEED);
    }
}

impl Hasher for FxHasher {
    #[inline]
    fn finish(&self) -> u64 {
        self.hash
    }

    #[inline]
    fn write(&mut self, bytes: &[u8]) {
        for chunk in bytes.chunks(8) {
            let mut buf = [0u8; 8];
            buf[..chunk.len()].copy_from_slice(chunk);
            self.add_to_hash(u64::from_le_bytes(buf));
        }
    }

    #[inline]
    fn write_u64(&mut self, i: u64) {
        self.add_to_hash(i);
    }

    #[inline]
    fn write_usize(&mut self, i: usize) {
        self.add_to_hash(i as u64);
    }

    #[inline]
    fn write_u32(&mut self, i: u32) {
        self.add_to_hash(i as u64);
    }
}

/// Drop-in `HashMap` with the fast hasher.
pub type FxHashMap<K, V> =
    std::collections::HashMap<K, V, BuildHasherDefault<FxHasher>>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn map_roundtrip() {
        let mut m: FxHashMap<u64, u32> = FxHashMap::default();
        for i in 0..1000u64 {
            m.insert(i, (i * 3) as u32);
        }
        for i in 0..1000u64 {
            assert_eq!(m.get(&i), Some(&((i * 3) as u32)));
        }
        assert_eq!(m.len(), 1000);
    }

    #[test]
    fn distinct_keys_distinct_hashes_mostly() {
        use std::hash::Hash;
        let mut seen = std::collections::HashSet::new();
        for i in 0..10_000u64 {
            let mut h = FxHasher::default();
            i.hash(&mut h);
            seen.insert(h.finish());
        }
        assert_eq!(seen.len(), 10_000, "collisions on sequential u64 keys");
    }

    #[test]
    fn byte_writes_consistent() {
        use std::hash::Hash;
        let mut a = FxHasher::default();
        "hello world, this is a key".hash(&mut a);
        let mut b = FxHasher::default();
        "hello world, this is a key".hash(&mut b);
        assert_eq!(a.finish(), b.finish());
    }
}
