//! Ablation: how much of Cronus's win comes from Algorithm 1?
//!
//! Compares the balanced split against fixed-fraction splits, full
//! disaggregation (split = whole prompt), and an idealized PP without the
//! vLLM scheduler barrier — the design choices DESIGN.md calls out.
//!
//! ```bash
//! cargo bench --bench ablation_balancer
//! ```

use cronus::baselines::pp::PpSystem;
use cronus::benchkit::Table;
use cronus::config::DeploymentConfig;
use cronus::cronus::balancer::SplitPolicy;
use cronus::cronus::frontend::CronusSystem;
use cronus::simgpu::model_desc::LLAMA3_8B;
use cronus::simgpu::spec::{A10, A100};
use cronus::systems::{replay_trace, ServingSystem};
use cronus::workload::arrival::{stamp, ArrivalProcess};
use cronus::workload::azure::{generate, AzureTraceConfig};

fn main() {
    let n = std::env::var("CRONUS_BENCH_N")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(500usize);
    let cfg = DeploymentConfig::paper(A100, A10, LLAMA3_8B);
    let trace = generate(n, &AzureTraceConfig::default(), 42);
    let trace = stamp(&trace, ArrivalProcess::AllAtOnce);

    let mut table = Table::new(
        format!("Balancer ablation (A100+A10, LLaMA3-8B, {n} requests, all-at-once)"),
        &["Policy", "thpt (req/s)", "TTFT p99 (s)", "TBT p99 (s)"],
    );
    let mut run = |label: &str, sys: &mut dyn ServingSystem| {
        let out = replay_trace(sys, &trace);
        table.row(vec![
            label.to_string(),
            format!("{:.2}", out.report.throughput_rps),
            format!("{:.3}", out.report.ttft_p99_s),
            format!("{:.4}", out.report.tbt_p99_s),
        ]);
    };

    run(
        "Balanced (Algorithm 1)",
        &mut CronusSystem::new(cfg.clone(), SplitPolicy::Balanced, false, "cronus"),
    );
    for frac in [0.25, 0.5, 0.75] {
        run(
            &format!("Fixed split {frac}"),
            &mut CronusSystem::new(
                cfg.clone(),
                SplitPolicy::FixedFraction(frac),
                false,
                "fixed",
            ),
        );
    }
    run(
        "Full split (= Disagg. L-H)",
        &mut CronusSystem::new(cfg.clone(), SplitPolicy::Full, false, "full"),
    );
    run("PP with vLLM sync barrier", &mut PpSystem::new(cfg.clone()));
    run(
        "PP idealized (no barrier)",
        &mut PpSystem::without_sync_barrier(cfg.clone()),
    );
    table.print();
    println!("\nexpected: Algorithm 1 ≥ every fixed fraction; full split loses");
    println!("most throughput; the idealized PP recovers much of PP's gap.");
}
