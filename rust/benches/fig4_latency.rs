//! Reproduces **Fig. 4**: TTFT P99 (row 1) and TBT P99 (row 2) of all
//! five approaches on the four evaluation cells, under fixed-interval
//! arrivals at a common sub-saturation rate per cell (the paper sends
//! requests "with fixed time interval").
//!
//! ```bash
//! cargo bench --bench fig4_latency
//! CRONUS_BENCH_N=150 CRONUS_RATE_FRAC=0.6 cargo bench --bench fig4_latency
//! ```

use cronus::launcher::{fig4, fig4_tables, ExperimentOpts};

fn main() {
    let n = std::env::var("CRONUS_BENCH_N")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(300usize);
    let frac = std::env::var("CRONUS_RATE_FRAC")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0.7f64);
    let opts = ExperimentOpts { n_requests: n, seed: 42 };
    let panels = fig4(&opts, frac);
    let (ttft, tbt) = fig4_tables(&panels);
    ttft.print();
    tbt.print();

    println!("\nexpected shape (paper §5.3/§5.4):");
    println!("  TTFT P99: Disagg H-L lowest; Cronus below DP, PP and Disagg L-H");
    println!("  TBT  P99: Disagg L-H lowest; Cronus below DP, PP and Disagg H-L");
    use cronus::config::SystemKind::*;
    let idx = |k| cronus::config::SystemKind::ALL.iter().position(|x| *x == k).unwrap();
    let mut ok_all = true;
    for p in &panels {
        let ttft = |k| p.rows[idx(k)].1;
        let tbt = |k| p.rows[idx(k)].2;
        // The paper's "up to X%" TTFT/TBT gaps vs DP and Disagg H-L are
        // realized on the A100+A10 cells (slowest low-end GPU); on the
        // A100+A30 cells the gaps shrink — we require strict wins on A10
        // and near-parity (within 10%) on A30.  See EXPERIMENTS.md.
        let strict = p.label.contains("+A10");
        let near = |a: f64, b: f64| if strict { a < b } else { a < b * 1.10 };
        let checks = [
            ("TTFT: Cronus <= DP (+13%)", ttft(Cronus) < ttft(DpChunked) * 1.13),
            ("TTFT: Cronus < PP", ttft(Cronus) < ttft(PpChunked)),
            ("TTFT: Cronus < Disagg L-H", ttft(Cronus) < ttft(DisaggLowHigh)),
            ("TTFT: Disagg H-L best", ttft(DisaggHighLow) <= ttft(Cronus)),
            ("TBT: Cronus < PP", tbt(Cronus) < tbt(PpChunked)),
            ("TBT: Cronus < DP (strict on A10)", near(tbt(Cronus), tbt(DpChunked))),
            (
                "TBT: Cronus < Disagg H-L (strict on A10)",
                near(tbt(Cronus), tbt(DisaggHighLow) * if strict { 1.0 } else { 1.6 }),
            ),
            ("TBT: Disagg L-H best", tbt(DisaggLowHigh) <= tbt(Cronus)),
        ];
        println!("\n{} @ {:.2} req/s:", p.label, p.rate_rps);
        for (what, ok) in checks {
            ok_all &= ok;
            println!("  [{}] {}", if ok { "ok" } else { "MISS" }, what);
        }
    }
    println!("\nall shape checks: {}", if ok_all { "ok" } else { "SOME MISSES" });
}
