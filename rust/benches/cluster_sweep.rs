//! Cluster scale-out sweep: 1 → N mixed (high-end, low-end) pairs behind
//! the cluster-level router, for every routing policy.  The scenario the
//! paper leaves unexplored — mixed-capability pairs under one frontend —
//! and the headline scaling claim of the cluster subsystem: with the
//! least-outstanding-tokens policy, 4 pairs sustain ≥ 3x the 1-pair
//! throughput despite the heterogeneous mix.
//!
//! ```bash
//! cargo bench --bench cluster_sweep                 # 400 requests, 8 pairs
//! CRONUS_BENCH_N=40 CRONUS_MAX_PAIRS=2 cargo bench --bench cluster_sweep
//! ```

use cronus::benchkit::time_once;
use cronus::cronus::router::RoutePolicy;
use cronus::launcher::{cluster_sweep, ClusterSweepPoint, ExperimentOpts};

fn main() {
    let n = std::env::var("CRONUS_BENCH_N")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(400usize);
    let max_pairs = std::env::var("CRONUS_MAX_PAIRS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(8usize);
    let opts = ExperimentOpts { n_requests: n, seed: 42 };

    let mut lot_points: Vec<ClusterSweepPoint> = Vec::new();
    let mut wall_total = 0.0;
    for policy in RoutePolicy::ALL {
        let ((table, points), wall) =
            time_once(|| cluster_sweep(&opts, policy, max_pairs, None));
        table.print();
        wall_total += wall;
        if policy == RoutePolicy::LeastOutstandingTokens {
            lot_points = points;
        }
    }

    println!("\nheadline-claim checks:");
    let at = |k: usize| lot_points.iter().find(|p| p.n_pairs == k);
    if let Some(p4) = at(4) {
        let ok = p4.scaling >= 3.0;
        println!(
            "  [{}] least-outstanding: 4-pair scaling {:.2}x >= 3x",
            if ok { "ok" } else { "MISS" },
            p4.scaling
        );
    } else {
        println!("  [--] 4-pair check skipped (swept only {max_pairs} pairs)");
    }
    for p in &lot_points {
        let finished = p.outcome.report.n_finished;
        if finished != n {
            println!("  [MISS] {} pairs finished {finished}/{n}", p.n_pairs);
        }
    }
    println!(
        "\n(total bench wall time {wall_total:.1}s, n={n}, policies={})",
        RoutePolicy::ALL.len()
    );
}
