//! Reproduces **Fig. 3**: the linearity of chunked-prefill iteration time
//! in (prefill context length, total decode context) on the high-end GPU
//! with 512-token chunks, reporting the regression's R² and MAPE as the
//! paper does (R² = 0.990, MAPE 0.8% on A100/LLaMA3-8B; the Eq. 2
//! prefill fit on A30 reaches R² = 0.993, MAPE 7.4%).
//!
//! ```bash
//! cargo bench --bench fig3_linear_fit
//! ```

use cronus::launcher::fig3;

fn main() {
    let noise = std::env::var("CRONUS_FIT_NOISE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0.008f64);
    fig3(noise, 42).print();
    println!("\npaper reference: chunked fit R²=0.990 MAPE 0.8% (A100/LLaMA3-8B),");
    println!("prefill Eq.2 fit R²=0.993 MAPE 7.4% (A30/LLaMA3-8B).");
}
