//! Ablation for the paper's §6 limitation: short-input / long-output
//! workloads are decode-bound, the high-end GPU saturates on decode, and
//! Cronus's edge over the baselines narrows (the PPI idles).
//!
//! ```bash
//! cargo bench --bench ablation_limits
//! ```

use cronus::benchkit::Table;
use cronus::config::{DeploymentConfig, SystemKind};
use cronus::simgpu::model_desc::LLAMA3_8B;
use cronus::simgpu::spec::{A10, A100};
use cronus::systems::{build_system, replay_trace};
use cronus::workload::arrival::{stamp, ArrivalProcess};
use cronus::workload::azure::{generate, AzureTraceConfig};

fn main() {
    let n = std::env::var("CRONUS_BENCH_N")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(400usize);
    let cfg = DeploymentConfig::paper(A100, A10, LLAMA3_8B);

    let workloads = [
        ("conversation (in 1014 / out 247)", AzureTraceConfig::default()),
        (
            "short-in / long-out (in 128 / out 512)",
            AzureTraceConfig::short_input_long_output(),
        ),
    ];
    for (label, wcfg) in workloads {
        let trace = generate(n, &wcfg, 42);
        let trace = stamp(&trace, ArrivalProcess::AllAtOnce);
        let mut table = Table::new(
            format!("{label} — {n} requests"),
            &["Approach", "thpt (req/s)", "tok/s", "PPI/low busy %"],
        );
        let mut cronus_rps = 0.0;
        let mut dp_rps = 0.0;
        for kind in SystemKind::ALL {
            let mut sys = build_system(kind, &cfg);
            let out = replay_trace(sys.as_mut(), &trace);
            if kind == SystemKind::Cronus {
                cronus_rps = out.report.throughput_rps;
            }
            if kind == SystemKind::DpChunked {
                dp_rps = out.report.throughput_rps;
            }
            let low_busy = out
                .instances
                .iter()
                .find(|i| i.name.contains("A10") || i.name.contains("low") || i.name.contains("PPI"))
                .map(|i| 100.0 * i.busy_time_s / out.report.makespan_s)
                .unwrap_or(0.0);
            table.row(vec![
                kind.name().to_string(),
                format!("{:.2}", out.report.throughput_rps),
                format!("{:.0}", out.report.token_throughput_tps),
                format!("{low_busy:.0}%"),
            ]);
        }
        table.print();
        println!("Cronus/DP ratio: {:.2}", cronus_rps / dp_rps);
    }
    println!("\nexpected: the Cronus/DP ratio and the PPI busy fraction both drop");
    println!("on the decode-bound workload (§6: decode bottlenecks the high-end GPU).");
}
