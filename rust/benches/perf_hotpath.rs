//! Micro-benchmarks of the coordinator hot paths (§Perf deliverable):
//! Balancer decision latency, engine planning/completion, KV allocator
//! ops, event-queue ops, and whole-simulation iteration rate.  Used by
//! the performance pass documented in EXPERIMENTS.md §Perf.
//!
//! ```bash
//! cargo bench --bench perf_hotpath
//! ```

use cronus::benchkit::{bench_fn, time_once};
use cronus::config::DeploymentConfig;
use cronus::cronus::balancer::{Balancer, SplitPolicy};
use cronus::cronus::frontend::CronusSystem;
use cronus::engine::{EngineInstance, EngineRequest};
use cronus::kvcache::BlockAllocator;
use cronus::simclock::{EventQueue, SimTime};
use cronus::simgpu::fit::calibrate;
use cronus::simgpu::link::LinkSpec;
use cronus::simgpu::model_desc::LLAMA3_8B;
use cronus::simgpu::perfmodel::PerfModel;
use cronus::simgpu::spec::{A10, A100};
use cronus::systems::replay_trace;
use cronus::workload::arrival::{stamp, ArrivalProcess};
use cronus::workload::azure::{generate, AzureTraceConfig};

fn main() {
    let mut results = Vec::new();

    // --- Balancer decision latency (target: < 2 µs/request) ---
    let ppi = PerfModel::new(A10, LLAMA3_8B);
    let cpi = PerfModel::new(A100, LLAMA3_8B);
    let (p, c) = calibrate(&ppi, &cpi, 512, 0.0, 1);
    let balancer = Balancer::new(SplitPolicy::Balanced, p, c, 512);
    let stats = cronus::engine::instance::EngineStats {
        n_decode: 64,
        decode_ctx_sum: 64 * 1300,
        n_prefilling: 2,
        waiting: 5,
        free_blocks: 20_000,
        block_size: 16,
        total_blocks: 30_000,
    };
    let mut acc = 0usize;
    results.push(bench_fn("balancer.split(2048) [512 candidates]", 100, 2000, || {
        acc += balancer.split(2048, &stats).partial_len;
    }));

    // --- KV allocator ops ---
    let mut alloc = BlockAllocator::new(40_000, 16);
    let mut id = 0u64;
    results.push(bench_fn("kv allocate(1014)+release", 100, 5000, || {
        id += 1;
        alloc.allocate(id, 1014).unwrap();
        alloc.release(id).unwrap();
    }));

    // --- Event queue push+pop ---
    let mut q: EventQueue<u64> = EventQueue::new();
    let mut t = 0u64;
    results.push(bench_fn("event queue push+pop", 1000, 100_000, || {
        t += 17;
        q.push(SimTime(t), t);
        q.pop();
    }));

    // --- Engine plan+complete on a realistic mixed batch ---
    let pm = PerfModel::new(A100, LLAMA3_8B);
    let mut engine = EngineInstance::new(
        "bench", pm, LinkSpec::INFINIBAND_100G, 512, 512, 16, 400_000,
    );
    for i in 0..256 {
        engine.submit(EngineRequest::whole(i, 800, 100_000)); // never finish
    }
    // Warm into steady decode state.
    for _ in 0..600 {
        let plan = engine.plan_iteration().unwrap();
        engine.complete_iteration(&plan);
    }
    results.push(bench_fn("engine plan+complete (256-decode batch)", 50, 2000, || {
        let plan = engine.plan_iteration().unwrap();
        engine.complete_iteration(&plan);
    }));

    // --- Whole-system simulation rate ---
    let cfg = DeploymentConfig::paper(A100, A10, LLAMA3_8B);
    let trace = generate(200, &AzureTraceConfig::default(), 42);
    let trace = stamp(&trace, ArrivalProcess::AllAtOnce);
    let (out, wall) = time_once(|| {
        let mut sys = CronusSystem::new(cfg.clone(), SplitPolicy::Balanced, false, "x");
        replay_trace(&mut sys, &trace)
    });
    let iters = out.instances.iter().map(|i| i.n_iterations).sum::<u64>();
    println!("\n== micro-benchmarks ==");
    for r in &results {
        println!("{}", r.summary());
    }
    println!("\n== whole-system rate ==");
    println!(
        "cronus sim: 200 requests, {iters} engine iterations in {wall:.3}s wall \
         ({:.0} iterations/s, {:.1} sim-s/wall-s)",
        iters as f64 / wall,
        out.report.makespan_s / wall
    );
    std::hint::black_box(acc);
}
