//! Micro-benchmarks of the coordinator hot paths (§Perf deliverable):
//! Balancer decision latency, engine planning/completion, KV allocator
//! ops, event-queue ops, and whole-simulation iteration rate.  Used by
//! the performance pass documented in EXPERIMENTS.md §Perf.
//!
//! Besides the human-readable summary, the harness emits a
//! machine-readable `BENCH_hotpath.json` (override the path with
//! `CRONUS_BENCH_JSON`; scale the whole-system trace with
//! `CRONUS_BENCH_N`).  The JSON schema is stable — CI archives the file
//! on every run so regressions are diffable across commits:
//!
//! ```json
//! {
//!   "schema_version": 1,
//!   "generated_by": "perf_hotpath",
//!   "benchmarks": [{"name", "iters", "mean_ns", "p50_ns", "p99_ns"}, ...],
//!   "whole_system": {"label", "n_requests", "engine_iterations",
//!                    "wall_s", "iterations_per_s", "sim_s_per_wall_s"}
//! }
//! ```
//!
//! ```bash
//! cargo bench --bench perf_hotpath
//! ```

use cronus::benchkit::{bench_fn, time_once, JVal};
use cronus::config::DeploymentConfig;
use cronus::cronus::balancer::{Balancer, SplitPolicy};
use cronus::cronus::frontend::CronusSystem;
use cronus::engine::{EngineInstance, EngineRequest, IterationPlan};
use cronus::kvcache::BlockAllocator;
use cronus::simclock::{EventQueue, SimTime};
use cronus::simgpu::fit::calibrate;
use cronus::simgpu::link::LinkSpec;
use cronus::simgpu::model_desc::LLAMA3_8B;
use cronus::simgpu::perfmodel::PerfModel;
use cronus::simgpu::spec::{A10, A100};
use cronus::systems::replay_trace;
use cronus::workload::arrival::{stamp, ArrivalProcess};
use cronus::workload::azure::{generate, AzureTraceConfig};

fn main() {
    let mut results = Vec::new();

    // --- Balancer decision latency (target: < 2 µs/request) ---
    let ppi = PerfModel::new(A10, LLAMA3_8B);
    let cpi = PerfModel::new(A100, LLAMA3_8B);
    let (p, c) = calibrate(&ppi, &cpi, 512, 0.0, 1);
    let balancer = Balancer::new(SplitPolicy::Balanced, p, c, 512);
    let stats = cronus::engine::instance::EngineStats {
        n_decode: 64,
        decode_ctx_sum: 64 * 1300,
        n_prefilling: 2,
        waiting: 5,
        free_blocks: 20_000,
        block_size: 16,
        total_blocks: 30_000,
    };
    let mut acc = 0usize;
    results.push(bench_fn("balancer.split(2048) [512 candidates]", 100, 2000, || {
        acc += balancer.split(2048, &stats).partial_len;
    }));

    // --- KV allocator ops ---
    let mut alloc = BlockAllocator::new(40_000, 16);
    let mut id = 0u64;
    results.push(bench_fn("kv allocate(1014)+release", 100, 5000, || {
        id += 1;
        alloc.allocate(id, 1014).unwrap();
        alloc.release(id).unwrap();
    }));

    // --- Event queue push+pop ---
    let mut q: EventQueue<u64> = EventQueue::new();
    let mut t = 0u64;
    results.push(bench_fn("event queue push+pop", 1000, 100_000, || {
        t += 17;
        q.push(SimTime(t), t);
        q.pop();
    }));

    // --- Engine plan+complete on a realistic mixed batch ---
    // Uses the zero-allocation scratch API exactly as the serving
    // systems do: one reusable plan + one reusable event buffer.
    let pm = PerfModel::new(A100, LLAMA3_8B);
    let mut engine = EngineInstance::new(
        "bench", pm, LinkSpec::INFINIBAND_100G, 512, 512, 16, 400_000,
    );
    for i in 0..256 {
        engine.submit(EngineRequest::whole(i, 800, 100_000)); // never finish
    }
    let mut plan = IterationPlan::default();
    let mut events = Vec::new();
    // Warm into steady decode state.
    for _ in 0..600 {
        assert!(engine.plan_iteration_into(&mut plan));
        engine.complete_iteration_into(&plan, &mut events);
    }
    results.push(bench_fn("engine plan+complete (256-decode batch)", 50, 2000, || {
        engine.plan_iteration_into(&mut plan);
        engine.complete_iteration_into(&plan, &mut events);
    }));

    // --- Engine stats snapshot (O(1) incremental counters) ---
    let mut ctx_acc = 0usize;
    results.push(bench_fn("engine stats() [256 running]", 100, 10_000, || {
        ctx_acc = ctx_acc.wrapping_add(engine.stats().decode_ctx_sum);
    }));

    // --- Whole-system simulation rate ---
    let n_requests: usize = std::env::var("CRONUS_BENCH_N")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(200);
    let cfg = DeploymentConfig::paper(A100, A10, LLAMA3_8B);
    let trace = generate(n_requests, &AzureTraceConfig::default(), 42);
    let trace = stamp(&trace, ArrivalProcess::AllAtOnce);
    let (out, wall) = time_once(|| {
        let mut sys = CronusSystem::new(cfg.clone(), SplitPolicy::Balanced, false, "x");
        replay_trace(&mut sys, &trace)
    });
    let iters = out.instances.iter().map(|i| i.n_iterations).sum::<u64>();
    let iterations_per_s = iters as f64 / wall;
    let sim_per_wall = out.report.makespan_s / wall;

    println!("\n== micro-benchmarks ==");
    for r in &results {
        println!("{}", r.summary());
    }
    println!("\n== whole-system rate ==");
    println!(
        "cronus sim: {n_requests} requests, {iters} engine iterations in {wall:.3}s wall \
         ({iterations_per_s:.0} iterations/s, {sim_per_wall:.1} sim-s/wall-s)",
    );

    // --- Machine-readable artifact (see EXPERIMENTS.md §Perf) ---
    let artifact = JVal::Obj(vec![
        ("schema_version".into(), JVal::Int(1)),
        ("generated_by".into(), JVal::Str("perf_hotpath".into())),
        (
            "benchmarks".into(),
            JVal::Arr(results.iter().map(|r| r.to_jval()).collect()),
        ),
        (
            "whole_system".into(),
            JVal::Obj(vec![
                ("label".into(), JVal::Str("cronus-sim".into())),
                ("n_requests".into(), JVal::Int(n_requests as u64)),
                ("engine_iterations".into(), JVal::Int(iters)),
                ("wall_s".into(), JVal::Num(wall)),
                ("iterations_per_s".into(), JVal::Num(iterations_per_s)),
                ("sim_s_per_wall_s".into(), JVal::Num(sim_per_wall)),
            ]),
        ),
    ]);
    let path = std::env::var("CRONUS_BENCH_JSON")
        .unwrap_or_else(|_| "BENCH_hotpath.json".to_string());
    std::fs::write(&path, artifact.render() + "\n")
        .unwrap_or_else(|e| panic!("writing {path}: {e}"));
    println!("\nwrote {path}");
    std::hint::black_box(acc);
    std::hint::black_box(ctx_acc);
}
