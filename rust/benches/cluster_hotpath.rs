//! Cluster stepping overhead vs fleet size: the event-calendar headline
//! measurement (EXPERIMENTS.md §Cluster-perf).
//!
//! The same open-loop trace is replayed through an N-pair cluster for
//! N ∈ {4, 16, 64, 256}; the simulated work is fixed by the trace, so
//! ns/arrival isolates the cluster-layer cost (routing + stepping +
//! event merging).  With the lazily-invalidated per-pair event calendar
//! (`submit`/`advance`/`next_event_at` touch only due pairs, O(due +
//! log N)) the per-arrival overhead must grow *sublinearly* in the pair
//! count — the pre-calendar stepper scanned all N pairs per arrival and
//! grew linearly.
//!
//! Besides the table, the bench emits a machine-readable
//! `BENCH_cluster_hotpath.json` (override with
//! `CRONUS_CLUSTER_BENCH_JSON`); CI validates the schema and archives
//! the artifact — record, don't gate on latency (CI machines are noisy).
//!
//! ```bash
//! cargo bench --bench cluster_hotpath                  # 512 requests, 4→256 pairs
//! CRONUS_BENCH_N=128 CRONUS_MAX_PAIRS=64 cargo bench --bench cluster_hotpath
//! ```

use cronus::benchkit::JVal;
use cronus::launcher::{cluster_hotpath_sweep, HotpathPoint};

fn main() {
    let n_requests = std::env::var("CRONUS_BENCH_N")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(512usize);
    let max_pairs = std::env::var("CRONUS_MAX_PAIRS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(256usize)
        .max(1);
    let rate_rps = 64.0;
    let seed = 42u64;

    let mut pair_counts: Vec<usize> =
        [4usize, 16, 64, 256].into_iter().filter(|&p| p <= max_pairs).collect();
    if pair_counts.is_empty() {
        pair_counts.push(max_pairs);
    }

    let (table, points) =
        cluster_hotpath_sweep(&pair_counts, n_requests, rate_rps, seed);
    table.print();

    // Headline claim: cluster overhead per arrival grows sublinearly in
    // the pair count (an O(N)-per-arrival stepper would track the
    // linear-growth line).
    let first = points.first().expect("at least one sweep point");
    let last = points.last().expect("at least one sweep point");
    let per_arrival_growth = last.ns_per_arrival / first.ns_per_arrival.max(1e-9);
    let linear_growth = last.n_pairs as f64 / first.n_pairs as f64;
    let sublinear = points.len() < 2 || per_arrival_growth < linear_growth;
    println!("\nheadline-claim check:");
    println!(
        "  [{}] per-arrival overhead grows sublinearly {} → {} pairs \
         ({:.2}x vs {:.0}x linear)",
        if sublinear { "ok" } else { "MISS" },
        first.n_pairs,
        last.n_pairs,
        per_arrival_growth,
        linear_growth,
    );

    // --- Machine-readable artifact (see EXPERIMENTS.md §Cluster-perf) ---
    let point_jval = |p: &HotpathPoint| -> JVal {
        JVal::Obj(vec![
            ("pairs".into(), JVal::Int(p.n_pairs as u64)),
            ("wall_s".into(), JVal::Num(p.wall_s)),
            ("ns_per_arrival".into(), JVal::Num(p.ns_per_arrival)),
            ("events".into(), JVal::Int(p.n_events)),
            ("events_per_s".into(), JVal::Num(p.events_per_s)),
            ("finished".into(), JVal::Int(p.outcome.report.n_finished as u64)),
            ("shed".into(), JVal::Int(p.outcome.report.n_rejected as u64)),
        ])
    };
    let artifact = JVal::Obj(vec![
        ("schema_version".into(), JVal::Int(1)),
        ("generated_by".into(), JVal::Str("cluster_hotpath".into())),
        (
            "workload".into(),
            JVal::Obj(vec![
                ("n_requests".into(), JVal::Int(n_requests as u64)),
                ("rate_rps".into(), JVal::Num(rate_rps)),
                ("seed".into(), JVal::Int(seed)),
                ("policy".into(), JVal::Str("least-outstanding".into())),
            ]),
        ),
        ("points".into(), JVal::Arr(points.iter().map(point_jval).collect())),
        (
            "checks".into(),
            JVal::Obj(vec![
                ("pairs_min".into(), JVal::Int(first.n_pairs as u64)),
                ("pairs_max".into(), JVal::Int(last.n_pairs as u64)),
                ("per_arrival_growth".into(), JVal::Num(per_arrival_growth)),
                ("linear_growth".into(), JVal::Num(linear_growth)),
                ("sublinear_per_arrival".into(), JVal::Bool(sublinear)),
            ]),
        ),
    ]);
    let path = std::env::var("CRONUS_CLUSTER_BENCH_JSON")
        .unwrap_or_else(|_| "BENCH_cluster_hotpath.json".to_string());
    std::fs::write(&path, artifact.render() + "\n")
        .unwrap_or_else(|e| panic!("writing {path}: {e}"));
    println!("wrote {path}");
}
