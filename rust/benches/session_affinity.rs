//! Closed-loop multi-turn sessions under every routing policy: the
//! KV-affinity headline measurement.  The same seeded session workload
//! is served closed-loop (turn *k+1* submitted only after turn *k*'s
//! finish plus think time) on a mixed-capability cluster; KV-affinity
//! routing must complete the same turns as least-outstanding-tokens
//! while executing strictly fewer prefill tokens (the resident session
//! prefixes are neither recomputed nor transferred).
//!
//! Besides the table, the bench emits a machine-readable
//! `BENCH_session_affinity.json` (override with
//! `CRONUS_SESSION_BENCH_JSON`); CI validates the schema and archives
//! the artifact — record, don't gate (see EXPERIMENTS.md §Sessions).
//!
//! ```bash
//! cargo bench --bench session_affinity                 # 120 sessions, 4 pairs
//! CRONUS_BENCH_N=40 CRONUS_MAX_PAIRS=2 cargo bench --bench session_affinity
//! ```

use cronus::benchkit::{time_once, JVal};
use cronus::config::ClusterConfig;
use cronus::cronus::router::RoutePolicy;
use cronus::launcher::{session_affinity_sweep, session_workload, SessionPoint};
use cronus::simgpu::model_desc::LLAMA3_8B;
use cronus::workload::session::total_turns;

fn main() {
    let n_sessions = std::env::var("CRONUS_BENCH_N")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(120usize);
    let max_pairs = std::env::var("CRONUS_MAX_PAIRS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(4usize);
    let seed = 42u64;
    let think_mean_s = 2.0;

    let sessions = session_workload(n_sessions, think_mean_s, seed);
    let n_turns = total_turns(&sessions);
    let cluster = ClusterConfig::mixed(max_pairs, LLAMA3_8B);
    let ((table, points), wall) =
        time_once(|| session_affinity_sweep(&sessions, &cluster, None));
    table.print();

    let lot = points
        .iter()
        .find(|pt| pt.policy == RoutePolicy::LeastOutstandingTokens)
        .expect("policy swept");
    let aff = points
        .iter()
        .find(|pt| pt.policy == RoutePolicy::KvAffinity)
        .expect("policy swept");

    println!("\nheadline-claim checks:");
    let equal_turns = aff.stats.n_finished_turns == lot.stats.n_finished_turns;
    println!(
        "  [{}] kv-affinity completes the same turns as least-outstanding \
         ({} vs {})",
        if equal_turns { "ok" } else { "MISS" },
        aff.stats.n_finished_turns,
        lot.stats.n_finished_turns
    );
    let fewer_prefill = aff.prefill_tokens_executed < lot.prefill_tokens_executed;
    println!(
        "  [{}] kv-affinity executes strictly fewer prefill tokens \
         ({} vs {}, {} saved, hit rate {:.0}%)",
        if fewer_prefill { "ok" } else { "MISS" },
        aff.prefill_tokens_executed,
        lot.prefill_tokens_executed,
        aff.outcome.report.prefill_tokens_saved,
        100.0 * aff.outcome.report.kv_hit_rate
    );
    println!(
        "\n(total bench wall time {wall:.1}s, {n_sessions} sessions / {n_turns} \
         turns, {max_pairs} pairs, policies={})",
        RoutePolicy::ALL.len()
    );

    // --- Machine-readable artifact (see EXPERIMENTS.md §Sessions) ---
    let policy_jval = |pt: &SessionPoint| -> JVal {
        let r = &pt.outcome.report;
        JVal::Obj(vec![
            ("policy".into(), JVal::Str(pt.policy.name().into())),
            ("finished_turns".into(), JVal::Int(pt.stats.n_finished_turns as u64)),
            ("shed".into(), JVal::Int(r.n_rejected as u64)),
            (
                "prefill_tokens_executed".into(),
                JVal::Int(pt.prefill_tokens_executed),
            ),
            ("kv_hits".into(), JVal::Int(r.n_kv_hits as u64)),
            ("kv_hit_rate".into(), JVal::Num(r.kv_hit_rate)),
            ("prefill_tokens_saved".into(), JVal::Int(r.prefill_tokens_saved)),
            ("throughput_rps".into(), JVal::Num(r.throughput_rps)),
            ("ttft_p99_s".into(), JVal::Num(r.ttft_p99_s)),
            ("tbt_p99_s".into(), JVal::Num(r.tbt_p99_s)),
            ("makespan_s".into(), JVal::Num(r.makespan_s)),
        ])
    };
    let artifact = JVal::Obj(vec![
        ("schema_version".into(), JVal::Int(1)),
        ("generated_by".into(), JVal::Str("session_affinity".into())),
        (
            "workload".into(),
            JVal::Obj(vec![
                ("n_sessions".into(), JVal::Int(n_sessions as u64)),
                ("n_turns".into(), JVal::Int(n_turns as u64)),
                ("n_pairs".into(), JVal::Int(max_pairs as u64)),
                ("think_mean_s".into(), JVal::Num(think_mean_s)),
                ("seed".into(), JVal::Int(seed)),
            ]),
        ),
        (
            "policies".into(),
            JVal::Arr(points.iter().map(policy_jval).collect()),
        ),
        (
            "checks".into(),
            JVal::Obj(vec![
                ("equal_finished_turns".into(), JVal::Bool(equal_turns)),
                ("affinity_fewer_prefill_tokens".into(), JVal::Bool(fewer_prefill)),
            ]),
        ),
        ("wall_s".into(), JVal::Num(wall)),
    ]);
    let path = std::env::var("CRONUS_SESSION_BENCH_JSON")
        .unwrap_or_else(|_| "BENCH_session_affinity.json".to_string());
    std::fs::write(&path, artifact.render() + "\n")
        .unwrap_or_else(|e| panic!("writing {path}: {e}"));
    println!("wrote {path}");
}
