//! Reproduces **Table 3**: relative GPU utilization of the two
//! disaggregated-prefill configurations — overall system throughput
//! divided by each instance's standalone maximum throughput.  The paper's
//! point: the low-end GPU saturates (~100%) while the high-end GPU idles
//! (11–54%), whichever way the stages are assigned.
//!
//! ```bash
//! cargo bench --bench table3_utilization
//! ```

use cronus::launcher::{table3, ExperimentOpts};

fn main() {
    let n = std::env::var("CRONUS_BENCH_N")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(500usize);
    let opts = ExperimentOpts { n_requests: n, seed: 42 };
    table3(&opts).print();
    println!("\npaper's Table 3 for reference (H-L: prefill/decode, L-H: prefill/decode):");
    println!("  A100+A10 LLaMA3-8B   11% /  97%    99% /  32%");
    println!("  A100+A10 Qwen2-7B    28% / 101%   104% /  25%");
    println!("  A100+A30 LLaMA3-8B   25% /  96%    98% /  47%");
    println!("  A100+A30 Qwen2-7B    54% / 100%    99% /  38%");
    println!("\nshape: in H-L the decode (low-end) column ≈ 100%; in L-H the");
    println!("prefill (low-end) column ≈ 100%; the high-end column is far lower.");
}
