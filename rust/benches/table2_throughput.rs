//! Reproduces **Table 2**: maximum throughput (requests/second) of every
//! approach on every (GPU pair, model) evaluation cell.  All 1000
//! requests arrive at t=0 as in the paper's measurement procedure.
//!
//! ```bash
//! cargo bench --bench table2_throughput            # paper-size (1000)
//! CRONUS_BENCH_N=200 cargo bench --bench table2_throughput
//! ```

use cronus::benchkit::time_once;
use cronus::launcher::{table2, ExperimentOpts};

fn main() {
    let n = std::env::var("CRONUS_BENCH_N")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(1000usize);
    let opts = ExperimentOpts { n_requests: n, seed: 42 };
    let ((table, data), wall) = time_once(|| table2(&opts));
    table.print();
    println!("\npaper's Table 2 for reference:");
    println!("  DP+Chunked   7.28  8.70  8.54 10.85");
    println!("  PP+Chunked   3.86  4.08  3.96  3.97");
    println!("  Disagg. H-L  1.31  3.45  2.93  6.74");
    println!("  Disagg. L-H  4.11  4.35  6.14  6.59");
    println!("  Cronus       7.39  8.29  8.70 10.27");
    // Headline claims (shape, not absolutes).
    let get = |label: &str, kind: cronus::config::SystemKind| -> f64 {
        data.iter()
            .find(|(l, k, _)| l == label && *k == kind)
            .map(|(_, _, v)| *v)
            .unwrap()
    };
    use cronus::config::SystemKind::*;
    let mut claims = Vec::new();
    for cell in [
        "A100+A10 llama3-8b",
        "A100+A10 qwen2-7b",
        "A100+A30 llama3-8b",
        "A100+A30 qwen2-7b",
    ] {
        let cronus_rps = get(cell, Cronus);
        claims.push((
            format!("{cell}: Cronus > PP"),
            cronus_rps > get(cell, PpChunked),
        ));
        claims.push((
            format!("{cell}: Cronus > Disagg L-H"),
            cronus_rps > get(cell, DisaggLowHigh),
        ));
        claims.push((
            format!("{cell}: Cronus > Disagg H-L"),
            cronus_rps > get(cell, DisaggHighLow),
        ));
        claims.push((
            format!("{cell}: Cronus within 20% of DP"),
            cronus_rps > 0.8 * get(cell, DpChunked),
        ));
    }
    println!("\nheadline-claim checks:");
    for (what, ok) in &claims {
        println!("  [{}] {}", if *ok { "ok" } else { "MISS" }, what);
    }
    println!("\n(total bench wall time {wall:.1}s, n={n})");
}
